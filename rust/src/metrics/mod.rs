//! Evaluation metrics (paper §V-A5).
//!
//! * **Latency** — time between request submission and the observatory
//!   *starting to process* it, including task-queue wait.
//! * **Throughput** — request bytes divided by total transfer time.
//! * **Recall** — fraction of pre-fetched bytes later accessed.
//! * Request accounting: how many requests reach the observatory
//!   (Table III), and how requests are served locally — split between
//!   previously cached and pre-fetched data (Fig. 13).

use std::collections::BTreeMap;

use crate::cache::reuse::ReuseHistogram;
use crate::util::json::Json;
use crate::util::stats::Accum;

/// How one demand request was (predominantly) served.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ServedBy {
    /// Entirely from the user's local DTN, data cached by earlier demand.
    LocalCache,
    /// Entirely from the local DTN, data placed there by pre-fetch/stream.
    LocalPrefetch,
    /// Some portion from a peer DTN's cache.
    Peer,
    /// Some portion from the observatory.
    Observatory,
}

/// Utilization of one labeled interior link over a run (tiered
/// topologies only; the VDC star has no interior).
#[derive(Debug, Clone)]
pub struct TierUtil {
    /// Tier label from the topology ("core", "regional", ...).
    pub tier: &'static str,
    pub from: usize,
    pub to: usize,
    /// Bytes carried over the run (all flows crossing the link).
    pub carried_bytes: f64,
    /// `carried / (capacity × simulated window)` ∈ [0, 1].
    pub utilization: f64,
}

/// Cache-hit accounting for one tier of the placement hierarchy
/// (DESIGN.md §12).  "edge" covers the client-DTN stores (local and
/// peer serves alike — the serving node's tier attributes the hit);
/// interior tiers cover their [`crate::simnet::CacheSite`] nodes.
#[derive(Debug, Clone)]
pub struct TierHits {
    /// Tier label from the topology ("edge", "regional", "core").
    pub tier: &'static str,
    /// Chunk-level demand hits served by this tier's caches.
    pub hits: u64,
    /// Bytes of those hits.
    pub byte_hits: f64,
    /// Hits on chunks whose resident copy was first inserted by a
    /// *different* user than the requester (≤ `hits`; only counted
    /// when inserter tracking is on, i.e. interior placements).
    pub cross_user_hits: u64,
    /// Sampled reuse-distance histogram over the tier's reference
    /// stream, merged across its nodes (empty when tracking is off).
    pub reuse: ReuseHistogram,
}

/// Per-cohort request accounting (DESIGN.md §14; populated only when
/// the workload's cohort axis is on, so default runs keep an empty
/// vector and diff clean against pre-realism reports).
#[derive(Debug, Clone, PartialEq)]
pub struct CohortStat {
    /// Cohort label ("interactive", "bulk", "campaign").
    pub cohort: &'static str,
    /// Demand requests finalized for users of this cohort.
    pub requests: u64,
    /// Those with any observatory-served portion.
    pub origin_requests: u64,
    /// Bytes served to this cohort.
    pub bytes: f64,
}

impl CohortStat {
    /// Fraction of the cohort's requests with an origin component —
    /// the per-cohort miss rate the realism sweep compares.
    pub fn origin_fraction(&self) -> f64 {
        if self.requests == 0 {
            0.0
        } else {
            self.origin_requests as f64 / self.requests as f64
        }
    }
}

/// Aggregated metrics for one simulation run.
#[derive(Debug, Default, Clone)]
pub struct RunMetrics {
    /// Per-request achieved throughput (bytes/s).
    pub throughput: Accum,
    /// Queue latency of requests that reached the observatory (s).
    pub latency: Accum,
    /// Demand requests, total.
    pub requests_total: u64,
    /// Demand requests with any observatory-served portion.
    pub requests_to_observatory: u64,
    /// Requests served entirely at the local DTN from demand-cached data.
    pub served_local_cache: u64,
    /// Requests served entirely at the local DTN from pre-fetched data
    /// (includes streamed pushes).
    pub served_local_prefetch: u64,
    /// Requests with a peer-DTN component.
    pub served_peer: u64,
    /// Bytes transferred out of the observatory (origin traffic).
    pub origin_bytes: f64,
    /// Bytes served from caches (local or peer).
    pub cache_bytes: f64,
    /// Bytes moved DTN→DTN by the placement strategy.
    pub placement_bytes: f64,
    /// Throughput of peer-DTN cache retrievals (bytes/s samples).
    pub peer_throughput: Accum,
    /// Total served bytes and total request elapsed time — the
    /// volume-weighted aggregate throughput (big transfers count
    /// proportionally, unlike the per-request mean).
    pub sum_bytes: f64,
    pub sum_elapsed: f64,
    /// Pre-fetch recall (set at end of run from the cache network).
    pub recall: f64,
    /// Peak concurrent transfers in flight (scheduler load indicator;
    /// the traffic-sweep experiment reports it alongside wall-clock).
    pub peak_flows: u64,
    /// Peak live per-request states in the coordinator — requests
    /// arrived but not yet finalized.  With the streaming arrival
    /// source this is the resident demand footprint of a run (the
    /// scale sweep reports it against the total request count).
    pub peak_req_states: u64,
    /// Slab slots ever allocated for request state — the coordinator's
    /// request-memory high-water mark.  Slots recycle on finalize, so
    /// this tracks peak concurrency, not request count; the scale sweep
    /// reports it to show the 10M-user run's footprint stays bounded.
    pub peak_slab_slots: u64,
    /// Interior-link utilization per labeled tier link (empty on the
    /// star; populated for hierarchical/federation topologies).
    pub interior_util: Vec<TierUtil>,
    /// Total chunk-level cache hits across every tier — always equals
    /// the sum of `tier_hits[..].hits` (audited under `sim-audit`).
    pub cache_hit_chunks: u64,
    /// Per-tier hit/byte-hit/cross-user accounting, "edge" first, then
    /// interior tiers in the topology's cache-site order.
    pub tier_hits: Vec<TierHits>,
    /// Fault events injected over the run (onsets; 0 when healthy).
    pub faults_injected: u64,
    /// Transfers severed mid-flight by link/node faults.
    pub flows_severed: u64,
    /// Severed transfers re-enqueued under the retry policy.
    pub retries: u64,
    /// Requests with any portion abandoned after the retry budget.
    pub requests_failed: u64,
    /// Bytes still undelivered at the moment flows were severed.  Each
    /// severed remainder lands in exactly one of `bytes_refetched`
    /// (a retry re-delivers it) or `bytes_abandoned` (budget
    /// exhausted), so `bytes_severed == bytes_refetched +
    /// bytes_abandoned` always — the fault conservation identity
    /// (asserted under `sim-audit` and by `scripts/check_report.py`).
    pub bytes_severed: f64,
    /// Severed bytes re-delivered by retries (resume-from-settled:
    /// only the remainder, never the whole transfer).
    pub bytes_refetched: f64,
    /// Severed bytes abandoned after the retry budget.
    pub bytes_abandoned: f64,
    /// Simulated seconds with ≥ 1 fault active (degradation windows).
    pub degraded_secs: f64,
    /// Origin bytes sent while ≥ 1 fault was active — the traffic
    /// shifted to the observatory during degradation.
    pub origin_bytes_degraded: f64,
    /// Elapsed time of requests finalized while ≥ 1 fault was active —
    /// the availability-adjusted delivery latency.
    pub degraded_latency: Accum,
    /// Peak arrivals in any one simulated minute — the burstiness
    /// signal the rhythm/flash axes move (DESIGN.md §14).
    pub peak_minute_arrivals: u64,
    /// Origin bytes sent while a flash-crowd window was active (0 when
    /// the flash axis is off).
    pub flash_origin_bytes: f64,
    /// Per-cohort accounting ("interactive"/"bulk"/"campaign" order;
    /// empty unless the cohort axis is on).
    pub cohort_stats: Vec<CohortStat>,
    /// Wall-clock spent in the run (for the §Perf log).
    pub wall_secs: f64,
}

impl RunMetrics {
    pub fn new() -> Self {
        Self {
            throughput: Accum::new(),
            latency: Accum::new(),
            peer_throughput: Accum::new(),
            degraded_latency: Accum::new(),
            ..Default::default()
        }
    }

    pub fn record_served(&mut self, served: ServedBy) {
        self.requests_total += 1;
        match served {
            ServedBy::LocalCache => self.served_local_cache += 1,
            ServedBy::LocalPrefetch => self.served_local_prefetch += 1,
            ServedBy::Peer => self.served_peer += 1,
            ServedBy::Observatory => self.requests_to_observatory += 1,
        }
    }

    /// Mean request throughput in Mbps (the unit of Tables IV-V).
    pub fn throughput_mbps(&self) -> f64 {
        crate::util::bytes_per_sec_to_mbps(self.throughput.mean())
    }

    /// Volume-weighted aggregate throughput in Mbps: total bytes over
    /// total per-request elapsed time.  Sensitive to how the big
    /// overlapping/human transfers are served, which is where cache
    /// capacity and eviction policy actually bite.
    pub fn agg_throughput_mbps(&self) -> f64 {
        if self.sum_elapsed <= 0.0 {
            0.0
        } else {
            crate::util::bytes_per_sec_to_mbps(self.sum_bytes / self.sum_elapsed)
        }
    }

    /// Mean queue latency (seconds).
    pub fn latency_secs(&self) -> f64 {
        self.latency.mean()
    }

    /// Fraction of requests that had to be served by the observatory
    /// (Table III's normalized count, with No-Cache ≡ 1.0).
    pub fn origin_fraction(&self) -> f64 {
        if self.requests_total == 0 {
            0.0
        } else {
            self.requests_to_observatory as f64 / self.requests_total as f64
        }
    }

    /// Fraction of requests with an abandoned (failed) portion.
    pub fn failure_fraction(&self) -> f64 {
        if self.requests_total == 0 {
            0.0
        } else {
            self.requests_failed as f64 / self.requests_total as f64
        }
    }

    /// Mean elapsed time of requests finalized during degradation
    /// windows (seconds) — the availability-adjusted delivery latency.
    /// 0 when no request finished under active faults.
    pub fn degraded_latency_secs(&self) -> f64 {
        self.degraded_latency.mean()
    }

    /// Fraction of requests served entirely from the local DTN,
    /// split (cached, pre-fetched) — Fig. 13's two bars.
    pub fn local_fractions(&self) -> (f64, f64) {
        if self.requests_total == 0 {
            return (0.0, 0.0);
        }
        let n = self.requests_total as f64;
        (
            self.served_local_cache as f64 / n,
            self.served_local_prefetch as f64 / n,
        )
    }

    /// Peak directed-link utilization and total carried bytes across a
    /// tier's interior links (the hot direction dominates downstream
    /// delivery, so the peak is the saturation signal).
    pub fn tier_summary(&self, tier: &str) -> (f64, f64) {
        let mut max_util = 0.0f64;
        let mut bytes = 0.0;
        for u in self.interior_util.iter().filter(|u| u.tier == tier) {
            max_util = max_util.max(u.utilization);
            bytes += u.carried_bytes;
        }
        (max_util, bytes)
    }

    /// Hit accounting for one tier, when the run recorded any.
    pub fn tier_hit(&self, tier: &str) -> Option<&TierHits> {
        self.tier_hits.iter().find(|t| t.tier == tier)
    }

    /// Fraction of cache hits (all tiers) that were cross-user — hits
    /// on chunks first inserted by a different user.  0 for edge-only
    /// runs, where inserter tracking is off.
    pub fn cross_user_hit_fraction(&self) -> f64 {
        let cross: u64 = self.tier_hits.iter().map(|t| t.cross_user_hits).sum();
        if self.cache_hit_chunks == 0 {
            0.0
        } else {
            cross as f64 / self.cache_hit_chunks as f64
        }
    }

    /// Network-traffic reduction at the observatory vs a no-cache run
    /// (the paper's headline 60.7% / 19.7%).
    pub fn traffic_reduction_vs(&self, baseline_origin_bytes: f64) -> f64 {
        if baseline_origin_bytes <= 0.0 {
            0.0
        } else {
            1.0 - self.origin_bytes / baseline_origin_bytes
        }
    }

    /// Machine-readable form of the run: every counter and accumulator
    /// plus the derived headline figures, for `RunReport` artifacts
    /// (`repro simulate --json`, experiment `<id>.json` files).
    pub fn to_json(&self) -> Json {
        let accum = |a: &Accum| {
            let mut m = BTreeMap::new();
            m.insert("count".to_string(), Json::Num(a.count as f64));
            m.insert("sum".to_string(), Json::Num(a.sum));
            m.insert("mean".to_string(), Json::Num(a.mean()));
            Json::Obj(m)
        };
        let mut m = BTreeMap::new();
        m.insert("requests_total".to_string(), Json::Num(self.requests_total as f64));
        m.insert(
            "requests_to_observatory".to_string(),
            Json::Num(self.requests_to_observatory as f64),
        );
        m.insert(
            "served_local_cache".to_string(),
            Json::Num(self.served_local_cache as f64),
        );
        m.insert(
            "served_local_prefetch".to_string(),
            Json::Num(self.served_local_prefetch as f64),
        );
        m.insert("served_peer".to_string(), Json::Num(self.served_peer as f64));
        m.insert("origin_bytes".to_string(), Json::Num(self.origin_bytes));
        m.insert("cache_bytes".to_string(), Json::Num(self.cache_bytes));
        m.insert("placement_bytes".to_string(), Json::Num(self.placement_bytes));
        m.insert("sum_bytes".to_string(), Json::Num(self.sum_bytes));
        m.insert("sum_elapsed".to_string(), Json::Num(self.sum_elapsed));
        m.insert("recall".to_string(), Json::Num(self.recall));
        m.insert("peak_flows".to_string(), Json::Num(self.peak_flows as f64));
        m.insert(
            "peak_req_states".to_string(),
            Json::Num(self.peak_req_states as f64),
        );
        m.insert(
            "peak_slab_slots".to_string(),
            Json::Num(self.peak_slab_slots as f64),
        );
        m.insert("wall_secs".to_string(), Json::Num(self.wall_secs));
        m.insert(
            "faults_injected".to_string(),
            Json::Num(self.faults_injected as f64),
        );
        m.insert("flows_severed".to_string(), Json::Num(self.flows_severed as f64));
        m.insert("retries".to_string(), Json::Num(self.retries as f64));
        m.insert(
            "requests_failed".to_string(),
            Json::Num(self.requests_failed as f64),
        );
        m.insert("bytes_severed".to_string(), Json::Num(self.bytes_severed));
        m.insert("bytes_refetched".to_string(), Json::Num(self.bytes_refetched));
        m.insert("bytes_abandoned".to_string(), Json::Num(self.bytes_abandoned));
        m.insert("degraded_secs".to_string(), Json::Num(self.degraded_secs));
        m.insert(
            "origin_bytes_degraded".to_string(),
            Json::Num(self.origin_bytes_degraded),
        );
        m.insert(
            "failure_fraction".to_string(),
            Json::Num(self.failure_fraction()),
        );
        m.insert(
            "degraded_latency_secs".to_string(),
            Json::Num(self.degraded_latency_secs()),
        );
        m.insert(
            "peak_minute_arrivals".to_string(),
            Json::Num(self.peak_minute_arrivals as f64),
        );
        m.insert(
            "flash_origin_bytes".to_string(),
            Json::Num(self.flash_origin_bytes),
        );
        m.insert(
            "cohort_stats".to_string(),
            Json::Arr(
                self.cohort_stats
                    .iter()
                    .map(|c| {
                        let mut s = BTreeMap::new();
                        s.insert("cohort".to_string(), Json::Str(c.cohort.to_string()));
                        s.insert("requests".to_string(), Json::Num(c.requests as f64));
                        s.insert(
                            "origin_requests".to_string(),
                            Json::Num(c.origin_requests as f64),
                        );
                        s.insert("bytes".to_string(), Json::Num(c.bytes));
                        s.insert(
                            "origin_fraction".to_string(),
                            Json::Num(c.origin_fraction()),
                        );
                        Json::Obj(s)
                    })
                    .collect(),
            ),
        );
        m.insert("throughput".to_string(), accum(&self.throughput));
        m.insert("latency".to_string(), accum(&self.latency));
        m.insert("peer_throughput".to_string(), accum(&self.peer_throughput));
        m.insert("degraded_latency".to_string(), accum(&self.degraded_latency));
        m.insert("throughput_mbps".to_string(), Json::Num(self.throughput_mbps()));
        m.insert(
            "agg_throughput_mbps".to_string(),
            Json::Num(self.agg_throughput_mbps()),
        );
        m.insert("latency_secs".to_string(), Json::Num(self.latency_secs()));
        m.insert("origin_fraction".to_string(), Json::Num(self.origin_fraction()));
        m.insert(
            "interior_util".to_string(),
            Json::Arr(
                self.interior_util
                    .iter()
                    .map(|u| {
                        let mut t = BTreeMap::new();
                        t.insert("tier".to_string(), Json::Str(u.tier.to_string()));
                        t.insert("from".to_string(), Json::Num(u.from as f64));
                        t.insert("to".to_string(), Json::Num(u.to as f64));
                        t.insert("carried_bytes".to_string(), Json::Num(u.carried_bytes));
                        t.insert("utilization".to_string(), Json::Num(u.utilization));
                        Json::Obj(t)
                    })
                    .collect(),
            ),
        );
        m.insert(
            "cache_hit_chunks".to_string(),
            Json::Num(self.cache_hit_chunks as f64),
        );
        m.insert(
            "cross_user_hit_fraction".to_string(),
            Json::Num(self.cross_user_hit_fraction()),
        );
        m.insert(
            "tier_hits".to_string(),
            Json::Arr(
                self.tier_hits
                    .iter()
                    .map(|t| {
                        let mut h = BTreeMap::new();
                        h.insert("tier".to_string(), Json::Str(t.tier.to_string()));
                        h.insert("hits".to_string(), Json::Num(t.hits as f64));
                        h.insert("byte_hits".to_string(), Json::Num(t.byte_hits));
                        h.insert(
                            "cross_user_hits".to_string(),
                            Json::Num(t.cross_user_hits as f64),
                        );
                        let mut r = BTreeMap::new();
                        r.insert("cold".to_string(), Json::Num(t.reuse.cold as f64));
                        r.insert("samples".to_string(), Json::Num(t.reuse.samples as f64));
                        r.insert(
                            "buckets".to_string(),
                            Json::Arr(
                                t.reuse.buckets.iter().map(|&b| Json::Num(b as f64)).collect(),
                            ),
                        );
                        h.insert("reuse".to_string(), Json::Obj(r));
                        Json::Obj(h)
                    })
                    .collect(),
            ),
        );
        Json::Obj(m)
    }

    /// Rebuild metrics from their [`RunMetrics::to_json`] form — the
    /// read side of the golden-report fixtures (`tests/golden.rs`).
    ///
    /// Only the fields [`RunMetrics::diff_bits`] compares are
    /// recovered (counters, float sums, accumulator count/sum, interior
    /// links); derived accumulator moments (`sum_sq`/min/max) are not
    /// serialized and come back at their defaults, and `wall_secs`
    /// round-trips but is excluded from diffing anyway.
    /// Returns `None` when a required key is missing or has the wrong
    /// shape, or an interior tier label is unknown.
    pub fn from_json(v: &Json) -> Option<RunMetrics> {
        let num = |key: &str| v.get(key)?.as_f64();
        let count = |key: &str| num(key).map(|n| n as u64);
        let accum = |key: &str| -> Option<Accum> {
            let a = v.get(key)?;
            let mut acc = Accum::new();
            acc.count = a.get("count")?.as_f64()? as u64;
            acc.sum = a.get("sum")?.as_f64()?;
            Some(acc)
        };
        // Tier labels are `&'static str` in `TierUtil`; intern against
        // the topology's label set instead of leaking arbitrary
        // strings (a new tier only needs adding there).
        let intern_tier = |s: &str| -> Option<&'static str> {
            crate::simnet::topology::TIER_LABELS
                .into_iter()
                .find(|t| *t == s)
        };
        let mut interior_util = Vec::new();
        for u in v.get("interior_util")?.as_arr()? {
            interior_util.push(TierUtil {
                tier: intern_tier(u.get("tier")?.as_str()?)?,
                from: u.get("from")?.as_f64()? as usize,
                to: u.get("to")?.as_f64()? as usize,
                carried_bytes: u.get("carried_bytes")?.as_f64()?,
                utilization: u.get("utilization")?.as_f64()?,
            });
        }
        let mut tier_hits = Vec::new();
        for t in v.get("tier_hits")?.as_arr()? {
            let r = t.get("reuse")?;
            let mut buckets = Vec::new();
            for b in r.get("buckets")?.as_arr()? {
                buckets.push(b.as_f64()? as u64);
            }
            tier_hits.push(TierHits {
                tier: intern_tier(t.get("tier")?.as_str()?)?,
                hits: t.get("hits")?.as_f64()? as u64,
                byte_hits: t.get("byte_hits")?.as_f64()?,
                cross_user_hits: t.get("cross_user_hits")?.as_f64()? as u64,
                reuse: ReuseHistogram {
                    cold: r.get("cold")?.as_f64()? as u64,
                    samples: r.get("samples")?.as_f64()? as u64,
                    buckets,
                },
            });
        }
        // Realism keys are *lenient*: fixtures written before the
        // workload-realism axes lack them, and a default-off run holds
        // zeros/empties anyway — so absence decodes to the defaults
        // instead of invalidating the fixture (forward compatibility,
        // tests/golden.rs).
        let lenient = |key: &str| v.get(key).and_then(Json::as_f64).unwrap_or(0.0);
        let intern_cohort = |s: &str| -> Option<&'static str> {
            crate::trace::realism::Cohort::ALL
                .into_iter()
                .map(|c| c.name())
                .find(|n| *n == s)
        };
        let mut cohort_stats = Vec::new();
        if let Some(arr) = v.get("cohort_stats").and_then(Json::as_arr) {
            for c in arr {
                cohort_stats.push(CohortStat {
                    cohort: intern_cohort(c.get("cohort")?.as_str()?)?,
                    requests: c.get("requests")?.as_f64()? as u64,
                    origin_requests: c.get("origin_requests")?.as_f64()? as u64,
                    bytes: c.get("bytes")?.as_f64()?,
                });
            }
        }
        Some(RunMetrics {
            throughput: accum("throughput")?,
            latency: accum("latency")?,
            peer_throughput: accum("peer_throughput")?,
            degraded_latency: accum("degraded_latency")?,
            requests_total: count("requests_total")?,
            requests_to_observatory: count("requests_to_observatory")?,
            served_local_cache: count("served_local_cache")?,
            served_local_prefetch: count("served_local_prefetch")?,
            served_peer: count("served_peer")?,
            origin_bytes: num("origin_bytes")?,
            cache_bytes: num("cache_bytes")?,
            placement_bytes: num("placement_bytes")?,
            sum_bytes: num("sum_bytes")?,
            sum_elapsed: num("sum_elapsed")?,
            recall: num("recall")?,
            peak_flows: count("peak_flows")?,
            peak_req_states: count("peak_req_states")?,
            peak_slab_slots: count("peak_slab_slots")?,
            interior_util,
            cache_hit_chunks: count("cache_hit_chunks")?,
            tier_hits,
            faults_injected: count("faults_injected")?,
            flows_severed: count("flows_severed")?,
            retries: count("retries")?,
            requests_failed: count("requests_failed")?,
            bytes_severed: num("bytes_severed")?,
            bytes_refetched: num("bytes_refetched")?,
            bytes_abandoned: num("bytes_abandoned")?,
            degraded_secs: num("degraded_secs")?,
            origin_bytes_degraded: num("origin_bytes_degraded")?,
            peak_minute_arrivals: lenient("peak_minute_arrivals") as u64,
            flash_origin_bytes: lenient("flash_origin_bytes"),
            cohort_stats,
            wall_secs: num("wall_secs")?,
        })
    }

    /// Field-by-field *bit* comparison against another run, wall-clock
    /// excluded.  Returns one human-readable line per mismatch (empty ⇒
    /// the runs are bit-identical) — the primitive behind the parity
    /// property tests, the golden-report harness, and `RunReport`
    /// diffing between trajectories.
    pub fn diff_bits(&self, other: &RunMetrics) -> Vec<String> {
        let mut diffs = Vec::new();
        let counters = [
            ("requests_total", self.requests_total, other.requests_total),
            (
                "requests_to_observatory",
                self.requests_to_observatory,
                other.requests_to_observatory,
            ),
            ("served_local_cache", self.served_local_cache, other.served_local_cache),
            (
                "served_local_prefetch",
                self.served_local_prefetch,
                other.served_local_prefetch,
            ),
            ("served_peer", self.served_peer, other.served_peer),
            ("peak_flows", self.peak_flows, other.peak_flows),
            ("peak_req_states", self.peak_req_states, other.peak_req_states),
            ("peak_slab_slots", self.peak_slab_slots, other.peak_slab_slots),
            ("throughput.count", self.throughput.count, other.throughput.count),
            ("latency.count", self.latency.count, other.latency.count),
            (
                "peer_throughput.count",
                self.peer_throughput.count,
                other.peer_throughput.count,
            ),
            ("cache_hit_chunks", self.cache_hit_chunks, other.cache_hit_chunks),
            ("faults_injected", self.faults_injected, other.faults_injected),
            ("flows_severed", self.flows_severed, other.flows_severed),
            ("retries", self.retries, other.retries),
            ("requests_failed", self.requests_failed, other.requests_failed),
            (
                "degraded_latency.count",
                self.degraded_latency.count,
                other.degraded_latency.count,
            ),
            (
                "peak_minute_arrivals",
                self.peak_minute_arrivals,
                other.peak_minute_arrivals,
            ),
        ];
        for (name, x, y) in counters {
            if x != y {
                diffs.push(format!("{name}: {x} vs {y}"));
            }
        }
        let floats = [
            ("origin_bytes", self.origin_bytes, other.origin_bytes),
            ("cache_bytes", self.cache_bytes, other.cache_bytes),
            ("placement_bytes", self.placement_bytes, other.placement_bytes),
            ("sum_bytes", self.sum_bytes, other.sum_bytes),
            ("sum_elapsed", self.sum_elapsed, other.sum_elapsed),
            ("recall", self.recall, other.recall),
            ("throughput.sum", self.throughput.sum, other.throughput.sum),
            ("latency.sum", self.latency.sum, other.latency.sum),
            (
                "peer_throughput.sum",
                self.peer_throughput.sum,
                other.peer_throughput.sum,
            ),
            ("bytes_severed", self.bytes_severed, other.bytes_severed),
            ("bytes_refetched", self.bytes_refetched, other.bytes_refetched),
            ("bytes_abandoned", self.bytes_abandoned, other.bytes_abandoned),
            ("degraded_secs", self.degraded_secs, other.degraded_secs),
            (
                "origin_bytes_degraded",
                self.origin_bytes_degraded,
                other.origin_bytes_degraded,
            ),
            (
                "degraded_latency.sum",
                self.degraded_latency.sum,
                other.degraded_latency.sum,
            ),
            (
                "flash_origin_bytes",
                self.flash_origin_bytes,
                other.flash_origin_bytes,
            ),
        ];
        for (name, x, y) in floats {
            if x.to_bits() != y.to_bits() {
                diffs.push(format!("{name}: {x} vs {y}"));
            }
        }
        if self.interior_util.len() != other.interior_util.len() {
            diffs.push(format!(
                "interior_util.len: {} vs {}",
                self.interior_util.len(),
                other.interior_util.len()
            ));
        } else {
            for (x, y) in self.interior_util.iter().zip(&other.interior_util) {
                if x.tier != y.tier {
                    diffs.push(format!("tier label: {} vs {}", x.tier, y.tier));
                } else if x.from != y.from || x.to != y.to {
                    diffs.push(format!(
                        "{} link: {}->{} vs {}->{}",
                        x.tier, x.from, x.to, y.from, y.to
                    ));
                } else if x.carried_bytes.to_bits() != y.carried_bytes.to_bits() {
                    diffs.push(format!(
                        "carried {} {}->{}: {} vs {}",
                        x.tier, x.from, x.to, x.carried_bytes, y.carried_bytes
                    ));
                } else if x.utilization.to_bits() != y.utilization.to_bits() {
                    diffs.push(format!(
                        "utilization {} {}->{}: {} vs {}",
                        x.tier, x.from, x.to, x.utilization, y.utilization
                    ));
                }
            }
        }
        if self.tier_hits.len() != other.tier_hits.len() {
            diffs.push(format!(
                "tier_hits.len: {} vs {}",
                self.tier_hits.len(),
                other.tier_hits.len()
            ));
        } else {
            for (x, y) in self.tier_hits.iter().zip(&other.tier_hits) {
                if x.tier != y.tier {
                    diffs.push(format!("tier_hits label: {} vs {}", x.tier, y.tier));
                } else if x.hits != y.hits {
                    diffs.push(format!("{} hits: {} vs {}", x.tier, x.hits, y.hits));
                } else if x.byte_hits.to_bits() != y.byte_hits.to_bits() {
                    diffs.push(format!(
                        "{} byte_hits: {} vs {}",
                        x.tier, x.byte_hits, y.byte_hits
                    ));
                } else if x.cross_user_hits != y.cross_user_hits {
                    diffs.push(format!(
                        "{} cross_user_hits: {} vs {}",
                        x.tier, x.cross_user_hits, y.cross_user_hits
                    ));
                } else if x.reuse != y.reuse {
                    diffs.push(format!(
                        "{} reuse histogram: {:?} vs {:?}",
                        x.tier, x.reuse, y.reuse
                    ));
                }
            }
        }
        if self.cohort_stats.len() != other.cohort_stats.len() {
            diffs.push(format!(
                "cohort_stats.len: {} vs {}",
                self.cohort_stats.len(),
                other.cohort_stats.len()
            ));
        } else {
            for (x, y) in self.cohort_stats.iter().zip(&other.cohort_stats) {
                if x.cohort != y.cohort {
                    diffs.push(format!("cohort label: {} vs {}", x.cohort, y.cohort));
                } else if x.requests != y.requests {
                    diffs.push(format!(
                        "{} requests: {} vs {}",
                        x.cohort, x.requests, y.requests
                    ));
                } else if x.origin_requests != y.origin_requests {
                    diffs.push(format!(
                        "{} origin_requests: {} vs {}",
                        x.cohort, x.origin_requests, y.origin_requests
                    ));
                } else if x.bytes.to_bits() != y.bytes.to_bits() {
                    diffs.push(format!("{} bytes: {} vs {}", x.cohort, x.bytes, y.bytes));
                }
            }
        }
        diffs
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn served_accounting() {
        let mut m = RunMetrics::new();
        m.record_served(ServedBy::LocalCache);
        m.record_served(ServedBy::LocalPrefetch);
        m.record_served(ServedBy::LocalPrefetch);
        m.record_served(ServedBy::Observatory);
        assert_eq!(m.requests_total, 4);
        assert_eq!(m.origin_fraction(), 0.25);
        let (c, p) = m.local_fractions();
        assert_eq!(c, 0.25);
        assert_eq!(p, 0.5);
    }

    #[test]
    fn throughput_unit_conversion() {
        let mut m = RunMetrics::new();
        m.throughput.add(1.25e9); // 10 Gbps in bytes/s
        assert!((m.throughput_mbps() - 10_000.0).abs() < 1e-6);
    }

    #[test]
    fn traffic_reduction() {
        let mut m = RunMetrics::new();
        m.origin_bytes = 40.0;
        assert!((m.traffic_reduction_vs(100.0) - 0.6).abs() < 1e-12);
        assert_eq!(m.traffic_reduction_vs(0.0), 0.0);
    }

    #[test]
    fn empty_metrics_are_zero() {
        let m = RunMetrics::new();
        assert_eq!(m.throughput_mbps(), 0.0);
        assert_eq!(m.latency_secs(), 0.0);
        assert_eq!(m.origin_fraction(), 0.0);
    }

    #[test]
    fn json_roundtrips_and_carries_expected_keys() {
        let mut m = RunMetrics::new();
        m.record_served(ServedBy::Observatory);
        m.origin_bytes = 1.5e9;
        m.throughput.add(2.0e8);
        let text = m.to_json().to_string_pretty();
        let v = Json::parse(&text).unwrap();
        assert_eq!(v.get("requests_total").unwrap().as_f64(), Some(1.0));
        assert_eq!(v.get("origin_bytes").unwrap().as_f64(), Some(1.5e9));
        assert!(v.get("throughput").unwrap().get("mean").is_some());
        assert!(v.get("interior_util").unwrap().as_arr().is_some());
    }

    #[test]
    fn from_json_round_trips_every_diffed_field() {
        let mut m = RunMetrics::new();
        m.record_served(ServedBy::Observatory);
        m.record_served(ServedBy::Peer);
        m.origin_bytes = 1.5e9 + 0.125;
        m.cache_bytes = 3.25e8;
        m.placement_bytes = 17.0;
        m.sum_bytes = 9.75e9;
        m.sum_elapsed = 123.456789012345;
        m.recall = 0.1 + 0.2; // deliberately not exactly 0.3
        m.peak_flows = 42;
        m.peak_req_states = 7;
        m.peak_slab_slots = 9;
        m.throughput.add(2.0e8);
        m.latency.add(0.125);
        m.peer_throughput.add(3.0e7);
        m.interior_util.push(TierUtil {
            tier: "core",
            from: 0,
            to: 3,
            carried_bytes: 1.0e12 + 0.5,
            utilization: 0.75,
        });
        m.cache_hit_chunks = 13;
        m.tier_hits.push(TierHits {
            tier: "edge",
            hits: 5,
            byte_hits: 1.25e6 + 0.375,
            cross_user_hits: 0,
            reuse: ReuseHistogram::default(),
        });
        m.tier_hits.push(TierHits {
            tier: "regional",
            hits: 8,
            byte_hits: 3.5e6,
            cross_user_hits: 3,
            reuse: ReuseHistogram { cold: 2, samples: 6, buckets: vec![1, 0, 5] },
        });
        m.faults_injected = 4;
        m.flows_severed = 3;
        m.retries = 2;
        m.requests_failed = 1;
        m.bytes_severed = 5.0e6 + 0.25;
        m.bytes_refetched = 4.0e6 + 0.25;
        m.bytes_abandoned = 1.0e6;
        m.degraded_secs = 1234.5;
        m.origin_bytes_degraded = 2.5e6;
        m.degraded_latency.add(17.5);
        m.peak_minute_arrivals = 321;
        m.flash_origin_bytes = 7.5e5 + 0.125;
        m.cohort_stats.push(CohortStat {
            cohort: "interactive",
            requests: 11,
            origin_requests: 4,
            bytes: 2.0e6 + 0.25,
        });
        m.cohort_stats.push(CohortStat {
            cohort: "campaign",
            requests: 2,
            origin_requests: 2,
            bytes: 9.0e6,
        });
        m.wall_secs = 1.25;
        let text = m.to_json().to_string_pretty();
        let back = RunMetrics::from_json(&Json::parse(&text).unwrap()).unwrap();
        assert!(m.diff_bits(&back).is_empty(), "{:?}", m.diff_bits(&back));
        // Unknown tier labels and missing keys are rejected, not
        // silently zeroed.
        assert!(RunMetrics::from_json(&Json::parse("{}").unwrap()).is_none());
        let bad = text.replace("\"core\"", "\"warp\"");
        assert!(RunMetrics::from_json(&Json::parse(&bad).unwrap()).is_none());
        // Interior-link drift is visible to the differ: utilization
        // and endpoints are compared, not just carried bytes.
        let mut u_drift = back.clone();
        u_drift.interior_util[0].utilization += 1e-9;
        assert_eq!(m.diff_bits(&u_drift).len(), 1);
        let mut e_drift = back.clone();
        e_drift.interior_util[0].to = 4;
        assert_eq!(m.diff_bits(&e_drift).len(), 1);
        // Tier-hit drift is visible too: cross-user counts and the
        // reuse histogram are compared bit-for-bit.
        let mut h_drift = back.clone();
        h_drift.tier_hits[1].cross_user_hits = 2;
        assert_eq!(m.diff_bits(&h_drift).len(), 1);
        let mut r_drift = back.clone();
        r_drift.tier_hits[1].reuse.buckets[2] = 4;
        assert_eq!(m.diff_bits(&r_drift).len(), 1);
        // Cohort drift is visible too.
        let mut c_drift = back;
        c_drift.cohort_stats[0].origin_requests = 5;
        assert_eq!(m.diff_bits(&c_drift).len(), 1);
    }

    #[test]
    fn from_json_is_lenient_about_realism_keys() {
        // Fixtures written before the realism axes lack the new keys;
        // they must decode to the (zero/empty) defaults, not fail —
        // the schema-forward-compatibility half of the golden harness.
        let mut m = RunMetrics::new();
        m.record_served(ServedBy::Observatory);
        m.peak_minute_arrivals = 9;
        let mut v = m.to_json();
        if let Json::Obj(map) = &mut v {
            map.remove("peak_minute_arrivals");
            map.remove("flash_origin_bytes");
            map.remove("cohort_stats");
        }
        let back = RunMetrics::from_json(&v).expect("old-schema report must still decode");
        assert_eq!(back.peak_minute_arrivals, 0);
        assert_eq!(back.flash_origin_bytes, 0.0);
        assert!(back.cohort_stats.is_empty());
        // A default-off run carries exactly those defaults, so the
        // decoded old fixture still diffs clean against it.
        let mut fresh = RunMetrics::new();
        fresh.record_served(ServedBy::Observatory);
        assert!(fresh.diff_bits(&back).is_empty());
        // Unknown cohort labels are rejected, mirroring tier interning.
        let mut m2 = m.clone();
        m2.cohort_stats.push(CohortStat {
            cohort: "interactive",
            requests: 1,
            origin_requests: 0,
            bytes: 1.0,
        });
        let bad = m2.to_json().to_string_pretty().replace("\"interactive\"", "\"wizard\"");
        assert!(RunMetrics::from_json(&Json::parse(&bad).unwrap()).is_none());
    }

    #[test]
    fn fault_metrics_derive_and_diff() {
        let mut m = RunMetrics::new();
        assert_eq!(m.failure_fraction(), 0.0);
        assert_eq!(m.degraded_latency_secs(), 0.0);
        m.requests_total = 8;
        m.requests_failed = 2;
        m.degraded_latency.add(10.0);
        m.degraded_latency.add(30.0);
        assert!((m.failure_fraction() - 0.25).abs() < 1e-12);
        assert!((m.degraded_latency_secs() - 20.0).abs() < 1e-12);
        // Fault drift is visible to the bit differ.
        let mut other = m.clone();
        other.bytes_refetched += 1.0;
        let diffs = m.diff_bits(&other);
        assert_eq!(diffs.len(), 1, "{diffs:?}");
        assert!(diffs[0].starts_with("bytes_refetched"), "{diffs:?}");
    }

    #[test]
    fn cross_user_fraction_aggregates_over_tiers() {
        let mut m = RunMetrics::new();
        assert_eq!(m.cross_user_hit_fraction(), 0.0);
        m.cache_hit_chunks = 10;
        m.tier_hits.push(TierHits {
            tier: "edge",
            hits: 6,
            byte_hits: 0.0,
            cross_user_hits: 1,
            reuse: ReuseHistogram::default(),
        });
        m.tier_hits.push(TierHits {
            tier: "core",
            hits: 4,
            byte_hits: 0.0,
            cross_user_hits: 3,
            reuse: ReuseHistogram::default(),
        });
        assert!((m.cross_user_hit_fraction() - 0.4).abs() < 1e-12);
        assert_eq!(m.tier_hit("core").unwrap().hits, 4);
        assert!(m.tier_hit("regional").is_none());
    }

    #[test]
    fn diff_bits_finds_exact_mismatches() {
        let mut a = RunMetrics::new();
        a.record_served(ServedBy::Peer);
        a.origin_bytes = 10.0;
        let b = a.clone();
        assert!(a.diff_bits(&b).is_empty());
        a.origin_bytes = 10.0 + 1e-12;
        let diffs = a.diff_bits(&b);
        assert_eq!(diffs.len(), 1, "{diffs:?}");
        assert!(diffs[0].starts_with("origin_bytes"), "{diffs:?}");
    }
}
