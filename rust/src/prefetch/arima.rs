//! Next-request-gap prediction (paper §IV-A2).
//!
//! The paper trains an ARIMA model on the n = 60 most recent request
//! timestamps of each program user and predicts the next one.  We use
//! the same forecasting family, batched: an AR(p) Yule-Walker fit on
//! the first-differenced inter-arrival series (≡ ARIMA(p,1,0)).
//!
//! Two interchangeable implementations sit behind [`GapPredictor`]:
//!
//! * [`RustArima`] — pure-Rust reference (this file): identical math to
//!   the Layer-2 JAX model, used in unit tests and as a no-artifact
//!   fallback.
//! * [`crate::runtime::Engine`] — the AOT path: the JAX/Pallas model
//!   lowered to HLO and executed on the PJRT CPU client.  The
//!   integration suite asserts both produce the same numbers.

/// Batched next-gap predictor interface.
pub trait GapPredictor {
    /// For each window of inter-arrival gaps (oldest first), forecast
    /// the next gap in seconds.  Implementations must accept windows of
    /// any length ≥ 2 (shorter histories are padded internally).
    fn predict_gaps(&mut self, windows: &[Vec<f64>]) -> Vec<f64>;

    /// Display name for experiment logs.
    fn name(&self) -> &'static str;
}

/// Window length the predictor operates on (the paper's n = 60).
pub const WINDOW: usize = 60;
/// AR order (matches the Layer-2 model's `AR_ORDER`).
pub const ORDER: usize = 8;
/// Ridge nugget keeping the Toeplitz solve stable for constant series
/// (matches `_RIDGE` in python/compile/model.py).
pub const RIDGE: f64 = 1e-5;

/// Pure-Rust batched AR(p) gap predictor.
#[derive(Debug, Default, Clone)]
pub struct RustArima;

impl RustArima {
    pub fn new() -> Self {
        Self
    }
}

impl GapPredictor for RustArima {
    fn predict_gaps(&mut self, windows: &[Vec<f64>]) -> Vec<f64> {
        windows.iter().map(|w| predict_next_gap(w)).collect()
    }

    fn name(&self) -> &'static str {
        "rust-arima"
    }
}

/// Left-pad (by repeating the first element) or left-truncate a gap
/// history to exactly [`WINDOW`] entries, newest last.
pub fn normalize_window(gaps: &[f64]) -> Vec<f64> {
    let mut w = Vec::with_capacity(WINDOW);
    if gaps.is_empty() {
        return vec![1.0; WINDOW];
    }
    if gaps.len() >= WINDOW {
        w.extend_from_slice(&gaps[gaps.len() - WINDOW..]);
    } else {
        let pad = WINDOW - gaps.len();
        w.extend(std::iter::repeat(gaps[0]).take(pad));
        w.extend_from_slice(gaps);
    }
    w
}

/// Forecast the next inter-arrival gap from a history of gaps.
/// Mirrors `python/compile/model.py::ar_predictor` exactly.
pub fn predict_next_gap(gaps: &[f64]) -> f64 {
    let x = normalize_window(gaps);
    // ARIMA d=1: first difference.
    let dx: Vec<f64> = x.windows(2).map(|w| w[1] - w[0]).collect();
    let r = autocorr(&dx, ORDER + 1);
    let (phi, _sigma2) = levinson_durbin(&r, ORDER);
    // One-step forecast: most recent differences first.
    let mut dnext = 0.0;
    for (k, p) in phi.iter().enumerate() {
        dnext += p * dx[dx.len() - 1 - k];
    }
    (x[x.len() - 1] + dnext).max(1e-3)
}

/// Biased mean-centered autocorrelation (mirrors the Pallas kernel).
pub fn autocorr(x: &[f64], num_lags: usize) -> Vec<f64> {
    let n = x.len();
    assert!(num_lags <= n, "num_lags {num_lags} > len {n}");
    let mean = x.iter().sum::<f64>() / n as f64;
    let xc: Vec<f64> = x.iter().map(|v| v - mean).collect();
    (0..num_lags)
        .map(|k| {
            let mut s = 0.0;
            for t in 0..n - k {
                s += xc[t] * xc[t + k];
            }
            s / n as f64
        })
        .collect()
}

/// Levinson-Durbin recursion solving the Yule-Walker system
/// (mirrors `model.levinson_durbin`). Returns (phi, innovation var).
pub fn levinson_durbin(r: &[f64], order: usize) -> (Vec<f64>, f64) {
    assert!(r.len() > order);
    let mut e = r[0] + RIDGE;
    let mut a: Vec<f64> = Vec::new();
    for m in 1..=order {
        let mut acc = r[m];
        for j in 1..m {
            acc -= a[j - 1] * r[m - j];
        }
        let k = acc / e;
        let mut new_a: Vec<f64> = (1..m).map(|j| a[j - 1] - k * a[m - j - 1]).collect();
        new_a.push(k);
        a = new_a;
        e *= 1.0 - k * k;
    }
    (a, e)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn constant_series_predicts_period() {
        let gaps = vec![3600.0; 30];
        let next = predict_next_gap(&gaps);
        assert!((next - 3600.0).abs() < 1.0, "next={next}");
    }

    #[test]
    fn noisy_periodic_close_to_period() {
        let mut rng = crate::util::rng::Rng::new(1);
        let gaps: Vec<f64> = (0..60).map(|_| rng.gauss(3600.0, 30.0)).collect();
        let next = predict_next_gap(&gaps);
        assert!((next - 3600.0).abs() < 180.0, "next={next}");
    }

    #[test]
    fn short_history_padded() {
        let next = predict_next_gap(&[100.0, 100.0, 100.0]);
        assert!((next - 100.0).abs() < 1.0, "next={next}");
    }

    #[test]
    fn empty_history_safe() {
        let next = predict_next_gap(&[]);
        assert!(next > 0.0);
    }

    #[test]
    fn positive_gap_guarantee() {
        // Wildly decreasing gaps cannot push the forecast below the floor.
        let gaps: Vec<f64> = (0..60).map(|i| 1000.0 / (i + 1) as f64).collect();
        assert!(predict_next_gap(&gaps) >= 1e-3);
    }

    #[test]
    fn autocorr_lag0_is_variance() {
        let x = [1.0, 2.0, 3.0, 4.0, 5.0];
        let r = autocorr(&x, 2);
        assert!((r[0] - 2.0).abs() < 1e-12); // var of 1..5 = 2
    }

    #[test]
    fn levinson_solves_toeplitz_system() {
        // Known AR(2): x_t = 0.6 x_{t-1} - 0.3 x_{t-2} + noise.
        let mut rng = crate::util::rng::Rng::new(3);
        let mut x = vec![0.0; 8000];
        for t in 2..x.len() {
            x[t] = 0.6 * x[t - 1] - 0.3 * x[t - 2] + rng.normal();
        }
        let r = autocorr(&x, 3);
        let (phi, e) = levinson_durbin(&r, 2);
        assert!((phi[0] - 0.6).abs() < 0.05, "phi={phi:?}");
        assert!((phi[1] + 0.3).abs() < 0.05, "phi={phi:?}");
        assert!(e > 0.0);
    }

    #[test]
    fn batched_predictor_matches_scalar() {
        let mut p = RustArima::new();
        let w1: Vec<f64> = (0..40).map(|i| 100.0 + (i % 3) as f64).collect();
        let w2 = vec![60.0; 20];
        let out = p.predict_gaps(&[w1.clone(), w2.clone()]);
        assert_eq!(out.len(), 2);
        assert!((out[0] - predict_next_gap(&w1)).abs() < 1e-12);
        assert!((out[1] - predict_next_gap(&w2)).abs() < 1e-12);
    }

    #[test]
    fn prop_forecast_finite_and_positive() {
        crate::util::prop::check("arima-finite", |rng| {
            let n = rng.int_range(2, 80);
            let gaps: Vec<f64> = (0..n).map(|_| rng.range(0.1, 1e5)).collect();
            let next = predict_next_gap(&gaps);
            assert!(next.is_finite() && next > 0.0, "next={next}");
        });
    }

    #[test]
    fn normalize_window_shapes() {
        assert_eq!(normalize_window(&[]).len(), WINDOW);
        assert_eq!(normalize_window(&vec![1.0; 10]).len(), WINDOW);
        assert_eq!(normalize_window(&vec![1.0; 100]).len(), WINDOW);
        let w = normalize_window(&[5.0, 6.0]);
        assert_eq!(w[WINDOW - 1], 6.0);
        assert_eq!(w[0], 5.0); // padded with first element
    }
}
