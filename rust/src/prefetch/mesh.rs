//! MD2 reference model: regional mesh + association rules + ARIMA
//! (Xiong et al., "Prefetching scheme for massive spatiotemporal data
//! in a smart city", paper §V-A2).
//!
//! The scheme overlays a regional mesh on the geography, mines
//! association rules between mesh *cells* (spatial correlation), and
//! uses ARIMA over each user's access times (temporal correlation).
//! Every request is treated identically — the same prediction strategy
//! for human and program users — which HPM improves on by routing
//! request types to specialized models (§V-A2, §V-B1).

use std::collections::HashMap;

use crate::prefetch::arima::GapPredictor;
use crate::prefetch::assoc::{AssocConfig, AssocModel};
use crate::prefetch::{Action, ModelKnobs, Prediction, PrefetchModel};
use crate::trace::{Request, StreamId, Trace, UserId};

/// Mesh cell edge length in the synthetic site geography.
const CELL_SIZE: f64 = 15.0;

/// MD2: mesh-cell association rules + per-user ARIMA timing.
pub struct MeshModel {
    /// Lead offset + prediction width ([`ModelKnobs::default`] is the
    /// paper configuration; the scenario API sweeps both).
    knobs: ModelKnobs,
    assoc: AssocModel,
    predictor: Box<dyn GapPredictor>,
    /// user → recent inter-arrival gaps (all requests, unclassified).
    gaps: HashMap<UserId, Vec<f64>>,
    /// user → last request (ts, range).
    last: HashMap<UserId, (f64, crate::trace::TimeRange)>,
    /// cell → (stream → popularity).
    cell_streams: HashMap<u32, HashMap<StreamId, u64>>,
    /// cell → cached top streams (rebuilt with the rules).
    cell_top: HashMap<u32, Vec<StreamId>>,
    /// Cached predicted gap per user (invalidated on large error).
    pred_cache: HashMap<UserId, f64>,
}

const GAP_CAP: usize = 64;

impl MeshModel {
    pub fn new(predictor: Box<dyn GapPredictor>) -> Self {
        Self::with_knobs(predictor, ModelKnobs::default())
    }

    pub fn with_knobs(predictor: Box<dyn GapPredictor>, knobs: ModelKnobs) -> Self {
        Self {
            knobs,
            assoc: AssocModel::new(AssocConfig::default()),
            predictor,
            gaps: HashMap::new(),
            last: HashMap::new(),
            cell_streams: HashMap::new(),
            cell_top: HashMap::new(),
            pred_cache: HashMap::new(),
        }
    }

    /// Top streams of a cell by popularity (cached; refreshed on
    /// rebuild so the per-request path stays allocation-free).
    fn top_of_cell(&mut self, cell: u32, n: usize) -> Vec<StreamId> {
        if let Some(top) = self.cell_top.get(&cell) {
            return top.clone();
        }
        let Some(pop) = self.cell_streams.get(&cell) else {
            return Vec::new();
        };
        let mut ranked: Vec<(StreamId, u64)> = pop.iter().map(|(s, c)| (*s, *c)).collect();
        ranked.sort_by_key(|(s, c)| (std::cmp::Reverse(*c), s.0));
        let top: Vec<StreamId> = ranked.into_iter().take(n).map(|(s, _)| s).collect();
        self.cell_top.insert(cell, top.clone());
        top
    }

    /// Mesh cell id for a site location.
    pub fn cell_of(x: f64, y: f64) -> u32 {
        let cx = (x / CELL_SIZE).floor() as i32 + 512;
        let cy = (y / CELL_SIZE).floor() as i32 + 512;
        ((cx as u32) << 16) | (cy as u32 & 0xFFFF)
    }

    fn predict_gap(&mut self, user: UserId) -> f64 {
        let Some(gaps) = self.gaps.get(&user) else {
            return 3600.0;
        };
        if gaps.len() < 2 {
            return gaps.last().copied().unwrap_or(3600.0);
        }
        let last_gap = *gaps.last().unwrap();
        if let Some(&cached) = self.pred_cache.get(&user) {
            // Reuse while the series stays close to the forecast.
            if (last_gap - cached).abs() <= 0.2 * cached.max(1.0) {
                return cached;
            }
        }
        // Fitting ARIMA on short / wildly-varying series is useless and
        // expensive (each fit is a device call on the PJRT path): gate
        // on series stability, else fall back to the last gap — the
        // same screening the reference model's training would apply.
        // simlint: allow(D005): `gaps` here is the per-user &Vec<f64> (ordered), shadowing the map field's name
        let mean = gaps.iter().sum::<f64>() / gaps.len() as f64;
        // simlint: allow(D005): same local Vec binding as above
        let var = gaps.iter().map(|g| (g - mean) * (g - mean)).sum::<f64>() / gaps.len() as f64;
        let cv = var.sqrt() / mean.max(1e-9);
        if gaps.len() < 8 || cv > 0.5 {
            self.pred_cache.insert(user, last_gap);
            return last_gap;
        }
        let pred = self.predictor.predict_gaps(&[gaps.clone()])[0];
        self.pred_cache.insert(user, pred);
        pred
    }
}

impl PrefetchModel for MeshModel {
    fn observe(&mut self, req: &Request, trace: &Trace) -> Vec<Action> {
        let site = trace.site(trace.stream(req.stream).site);
        let cell = Self::cell_of(site.x, site.y);
        self.assoc.observe(req.user.0, cell, req.ts);
        *self
            .cell_streams
            .entry(cell)
            .or_default()
            .entry(req.stream)
            .or_insert(0) += 1;

        let prev = self.last.insert(req.user, (req.ts, req.range));
        if let Some((prev_ts, _)) = prev {
            let g = self.gaps.entry(req.user).or_default();
            if g.len() == GAP_CAP {
                g.remove(0);
            }
            g.push((req.ts - prev_ts).max(1e-3));
        } else {
            return Vec::new();
        }

        if !self.assoc.built {
            return Vec::new();
        }

        // Spatial: predicted next cells from the session's cells.
        let session = self.assoc.session_items(req.user.0).to_vec();
        let mut cells = self.assoc.predict(&session, self.knobs.top_n);
        // Fall back to the current cell when rules don't fire (the
        // scheme still prefetches popular content of the active region).
        if cells.is_empty() {
            cells.push(cell);
        }

        // Temporal: ARIMA gap forecast; pre-fetch the window advanced
        // to the predicted next access.
        let gap = self.predict_gap(req.user).max(1.0);
        let fire_at = req.ts + self.knobs.offset * gap;
        let range = crate::trace::TimeRange::new(req.range.start + gap, req.range.end + gap);

        let mut out = Vec::new();
        let mut budget = self.knobs.top_n;
        for c in cells {
            if budget == 0 {
                break;
            }
            for stream in self.top_of_cell(c, budget) {
                out.push(Action::Prefetch(Prediction {
                    user: req.user,
                    stream,
                    range,
                    fire_at,
                }));
                budget -= 1;
                if budget == 0 {
                    break;
                }
            }
        }
        out
    }

    fn rebuild(&mut self, _now: f64) {
        self.assoc.rebuild();
        self.cell_top.clear(); // refresh popularity ranking
    }

    fn name(&self) -> &'static str {
        "MD2"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prefetch::arima::RustArima;
    use crate::trace::{generator, presets, TimeRange};

    fn mk_trace() -> Trace {
        generator::generate(&presets::tiny())
    }

    fn mk_model() -> MeshModel {
        MeshModel::new(Box::new(RustArima::new()))
    }

    fn req(trace: &Trace, user: u32, ts: f64, stream: u32) -> Request {
        Request {
            user: UserId(user),
            ts,
            stream: StreamId(stream % trace.streams.len() as u32),
            range: TimeRange::new((ts - 100.0).max(0.0), ts.max(1.0)),
        }
    }

    #[test]
    fn cell_ids_group_nearby_sites() {
        let a = MeshModel::cell_of(1.0, 1.0);
        let b = MeshModel::cell_of(5.0, 5.0);
        let c = MeshModel::cell_of(100.0, 100.0);
        assert_eq!(a, b);
        assert_ne!(a, c);
    }

    #[test]
    fn no_predictions_before_rules_built() {
        let trace = mk_trace();
        let mut m = mk_model();
        for i in 0..5 {
            let acts = m.observe(&req(&trace, 1, i as f64 * 100.0, i), &trace);
            assert!(acts.is_empty());
        }
    }

    #[test]
    fn predicts_after_rebuild() {
        let trace = mk_trace();
        let mut m = mk_model();
        // Train with a repeating cell pattern across users/sessions.
        let mut ts = 0.0;
        for round in 0..30 {
            for s in 0..4u32 {
                m.observe(&req(&trace, round % 5, ts, s), &trace);
                ts += 10.0;
            }
            ts += 5000.0; // close sessions
        }
        m.rebuild(ts);
        let acts = m.observe(&req(&trace, 0, ts + 10.0, 0), &trace);
        // Popular cells exist, so MD2 prefetches something.
        assert!(!acts.is_empty());
        assert!(acts.len() <= crate::prefetch::ASSOC_TOP_N);
        for a in &acts {
            match a {
                Action::Prefetch(p) => assert!(p.fire_at > ts),
                other => panic!("MD2 must not subscribe: {other:?}"),
            }
        }
    }

    #[test]
    fn uniform_strategy_prefetches_for_program_style_users_too() {
        // The defining MD2 behaviour: no classification — a strictly
        // periodic user is treated like any other.
        let trace = mk_trace();
        let mut m = mk_model();
        let mut ts = 0.0;
        for round in 0..40 {
            m.observe(&req(&trace, 7, ts, 0), &trace);
            ts += 3600.0;
            if round == 20 {
                m.rebuild(ts);
            }
        }
        let acts = m.observe(&req(&trace, 7, ts, 0), &trace);
        assert!(!acts.is_empty());
    }
}
