//! HPM — the paper's Hybrid Pre-fetching Model (§IV-A).
//!
//! The hybrid routes each request by its classified type:
//!
//! * **Program requests** (regular / overlapping series) → the
//!   *history-based* model: an ARIMA-family forecast of the next
//!   request time from the series' 60 most recent gaps, then a
//!   pre-fetch scheduled at `ts_i + 0.8·(ts_pred − ts_i)` for the same
//!   moving window advanced to the predicted time.
//! * **Real-time requests** → the *streaming mechanism*: emit a
//!   [`Action::Subscribe`] so the push engine converts the polling
//!   series into server-side pushes (§IV-B).
//! * **Human / unclassified requests** → *association-rule mining*
//!   (FP-Growth): predict the top-3 co-browsed objects within the same
//!   time range as the last request, with the next time step estimated
//!   from the last two requests (§IV-A3).
//!
//! The gap forecaster is pluggable ([`GapPredictor`]): the pure-Rust
//! fallback or the AOT-compiled JAX/Pallas model through PJRT.  A
//! per-series forecast cache avoids re-running the model while a
//! series stays on its predicted schedule, so device calls scale with
//! the number of *series*, not requests.

use std::collections::HashMap;

use crate::prefetch::arima::GapPredictor;
use crate::prefetch::assoc::{AssocConfig, AssocModel};
use crate::prefetch::{Action, ModelKnobs, Prediction, PrefetchModel};
use crate::trace::classifier::{OnlineClassifier, ProgramClass};
use crate::trace::{Request, StreamId, TimeRange, Trace, UserId};

/// Relative forecast error beyond which the cached gap is invalidated
/// and the model re-run.
const CACHE_TOLERANCE: f64 = 0.2;

/// The hybrid pre-fetching model.
pub struct Hpm {
    /// Lead offset + prediction width ([`ModelKnobs::default`] is the
    /// paper configuration; the scenario API sweeps both).
    knobs: ModelKnobs,
    classifier: OnlineClassifier,
    assoc: AssocModel,
    predictor: Box<dyn GapPredictor>,
    /// Cached next-gap forecast per program series.
    gap_cache: HashMap<(UserId, StreamId), f64>,
    /// user → previous request ts (human time-step estimation).
    prev_ts: HashMap<UserId, f64>,
    /// Device/model call counter (perf accounting).
    pub predictor_calls: u64,
}

impl Hpm {
    pub fn new(predictor: Box<dyn GapPredictor>) -> Self {
        Self::with_assoc_config(predictor, AssocConfig::default())
    }

    pub fn with_knobs(predictor: Box<dyn GapPredictor>, knobs: ModelKnobs) -> Self {
        let mut hpm = Self::new(predictor);
        hpm.knobs = knobs;
        hpm
    }

    pub fn with_assoc_config(predictor: Box<dyn GapPredictor>, cfg: AssocConfig) -> Self {
        Self {
            knobs: ModelKnobs::default(),
            classifier: OnlineClassifier::new(),
            assoc: AssocModel::new(cfg),
            predictor,
            gap_cache: HashMap::new(),
            prev_ts: HashMap::new(),
            predictor_calls: 0,
        }
    }

    pub fn classifier(&self) -> &OnlineClassifier {
        &self.classifier
    }

    /// Forecast the next gap of a program series, using the cache while
    /// the series stays on schedule.
    fn forecast_gap(&mut self, user: UserId, stream: StreamId) -> f64 {
        let gaps = self.classifier.gap_history(user, stream);
        let last_gap = gaps.last().copied().unwrap_or(3600.0);
        let key = (user, stream);
        if let Some(&cached) = self.gap_cache.get(&key) {
            if (last_gap - cached).abs() <= CACHE_TOLERANCE * cached.max(1.0) {
                return cached;
            }
        }
        let pred = self.predictor.predict_gaps(&[gaps])[0];
        self.predictor_calls += 1;
        self.gap_cache.insert(key, pred);
        pred
    }

    /// History-based prediction for a regular/overlapping series.
    fn history_predict(&mut self, req: &Request) -> Vec<Action> {
        let gap = self.forecast_gap(req.user, req.stream).max(1.0);
        let pred_ts = req.ts + gap;
        // Moving window: same duration as the last request, ending at
        // the predicted request time (what program users actually ask).
        let window = req.range.duration();
        let range = TimeRange::new((pred_ts - window).max(0.0), pred_ts);
        vec![Action::Prefetch(Prediction {
            user: req.user,
            stream: req.stream,
            range,
            fire_at: req.ts + self.knobs.offset * gap,
        })]
    }

    /// Association-rule prediction for human/unclassified requests.
    fn assoc_predict(&mut self, req: &Request, prev_ts: Option<f64>) -> Vec<Action> {
        if !self.assoc.built {
            return Vec::new();
        }
        let session = self.assoc.session_items(req.user.0).to_vec();
        let objects = self.assoc.predict(&session, self.knobs.top_n);
        if objects.is_empty() {
            return Vec::new();
        }
        // ts_{i+1} = ts_i + (ts_i − ts_{i−1}); tr_{i+1} = tr_i (§IV-A3).
        let step = prev_ts.map(|p| (req.ts - p).max(1.0)).unwrap_or(60.0);
        let fire_at = req.ts + self.knobs.offset * step;
        objects
            .into_iter()
            .map(|obj| {
                Action::Prefetch(Prediction {
                    user: req.user,
                    stream: StreamId(obj),
                    range: req.range,
                    fire_at,
                })
            })
            .collect()
    }
}

impl PrefetchModel for Hpm {
    fn observe(&mut self, req: &Request, _trace: &Trace) -> Vec<Action> {
        self.classifier.observe(req);
        self.assoc.observe(req.user.0, req.stream.0, req.ts);
        let prev = self.prev_ts.insert(req.user, req.ts);

        match self.classifier.classify_series(req.user, req.stream) {
            Some(ProgramClass::Realtime) => {
                // Streaming mechanism: push cadence = the classifier's
                // cached median gap (O(1); no per-request sorting).
                let period = self
                    .classifier
                    .series_median_gap(req.user, req.stream)
                    .unwrap_or(60.0);
                vec![Action::Subscribe {
                    user: req.user,
                    stream: req.stream,
                    period,
                }]
            }
            Some(_) => self.history_predict(req),
            None => self.assoc_predict(req, prev),
        }
    }

    fn rebuild(&mut self, _now: f64) {
        self.assoc.rebuild();
    }

    fn name(&self) -> &'static str {
        "HPM"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prefetch::arima::RustArima;
    use crate::trace::{generator, presets};

    fn mk_trace() -> Trace {
        generator::generate(&presets::tiny())
    }

    fn mk_hpm() -> Hpm {
        Hpm::new(Box::new(RustArima::new()))
    }

    fn req(user: u32, ts: f64, stream: u32, start: f64, end: f64) -> Request {
        Request {
            user: UserId(user),
            ts,
            stream: StreamId(stream),
            range: TimeRange::new(start, end),
        }
    }

    #[test]
    fn hourly_series_gets_history_prefetch() {
        let trace = mk_trace();
        let mut hpm = mk_hpm();
        let mut last = Vec::new();
        for i in 0..10 {
            let t = i as f64 * 3600.0;
            last = hpm.observe(&req(1, t, 0, t - 3600.0, t), &trace);
        }
        assert_eq!(last.len(), 1);
        match &last[0] {
            Action::Prefetch(p) => {
                assert_eq!(p.stream, StreamId(0));
                // Predicted one period ahead, fired at the 0.8 offset.
                let t_last = 9.0 * 3600.0;
                assert!((p.fire_at - (t_last + 0.8 * 3600.0)).abs() < 120.0, "fire {}", p.fire_at);
                assert!((p.range.end - (t_last + 3600.0)).abs() < 120.0);
                assert!((p.range.duration() - 3600.0).abs() < 1.0);
            }
            other => panic!("expected prefetch, got {other:?}"),
        }
    }

    #[test]
    fn minutely_series_gets_subscription() {
        let trace = mk_trace();
        let mut hpm = mk_hpm();
        let mut last = Vec::new();
        for i in 0..20 {
            let t = i as f64 * 60.0;
            last = hpm.observe(&req(2, t, 1, t - 60.0, t), &trace);
        }
        match &last[0] {
            Action::Subscribe { user, stream, period } => {
                assert_eq!(*user, UserId(2));
                assert_eq!(*stream, StreamId(1));
                assert!((*period - 60.0).abs() < 1.0);
            }
            other => panic!("expected subscribe, got {other:?}"),
        }
    }

    #[test]
    fn human_requests_use_association_rules() {
        let trace = mk_trace();
        let mut hpm = mk_hpm();
        // Train: many users co-browse streams {3, 4, 5} in sessions.
        let mut ts = 0.0;
        for u in 10..25 {
            for s in [3u32, 4, 5] {
                hpm.observe(&req(u, ts, s, ts - 500.0, ts), &trace);
                ts += 30.0;
            }
            ts += 5000.0;
        }
        hpm.rebuild(ts);
        // A fresh user browses 3 then 4 → expect 5 predicted.
        let _ = hpm.observe(&req(99, ts, 3, ts - 500.0, ts), &trace);
        let acts = hpm.observe(&req(99, ts + 40.0, 4, ts - 500.0, ts), &trace);
        let streams: Vec<u32> = acts
            .iter()
            .map(|a| match a {
                Action::Prefetch(p) => p.stream.0,
                _ => panic!("unexpected subscribe"),
            })
            .collect();
        assert!(streams.contains(&5), "streams={streams:?}");
        // Range identical to the last request (§IV-A3).
        if let Action::Prefetch(p) = &acts[0] {
            assert_eq!(p.range, TimeRange::new(ts - 500.0, ts));
        }
    }

    #[test]
    fn predictor_cache_limits_model_calls() {
        let trace = mk_trace();
        let mut hpm = mk_hpm();
        for i in 0..50 {
            let t = i as f64 * 3600.0;
            hpm.observe(&req(1, t, 0, t - 3600.0, t), &trace);
        }
        // Constant-period series: the cache should hold after the first
        // forecast — far fewer calls than observations.
        assert!(
            hpm.predictor_calls <= 3,
            "predictor called {} times for a constant series",
            hpm.predictor_calls
        );
    }

    #[test]
    fn no_assoc_predictions_before_rebuild() {
        let trace = mk_trace();
        let mut hpm = mk_hpm();
        let acts = hpm.observe(&req(50, 10.0, 2, 0.0, 10.0), &trace);
        assert!(acts.is_empty());
    }

    #[test]
    fn classified_series_switch_models() {
        let trace = mk_trace();
        let mut hpm = mk_hpm();
        // The same user has one periodic series (stream 0) and one-off
        // browsing (stream 7): only the periodic one gets history
        // prefetches.
        for i in 0..10 {
            let t = i as f64 * 3600.0;
            let acts = hpm.observe(&req(1, t, 0, t - 3600.0, t), &trace);
            if i >= 5 {
                assert!(matches!(acts[0], Action::Prefetch(_)));
            }
            // Quadratically growing timestamps: every gap differs, so the
            // stream-7 series can never look periodic.
            let t7 = (i * i) as f64 * 1000.0 + 7.0;
            let acts2 = hpm.observe(&req(1, t7, 7, 0.0, 100.0 + i as f64), &trace);
            // Unclassified + no rules → nothing.
            assert!(acts2.is_empty(), "i={i}: {acts2:?}");
        }
    }
}
