//! Data pre-fetching models (paper §IV-A, §IV-B, §V-A2).
//!
//! * [`arima`] — next-gap forecasting (history-based prediction core).
//! * [`fpgrowth`] — FP-tree / FP-Growth frequent-itemset mining.
//! * [`assoc`] — association-rule prediction over data objects.
//! * [`hybrid`] — **HPM**, the paper's contribution: classifier-routed
//!   hybrid of history-based ARIMA (program users), association rules
//!   (human users) and streaming subscriptions (real-time users).
//! * [`markov`] — **MD1** baseline (Li et al.): Markov model over
//!   geospatial access paths.
//! * [`mesh`] — **MD2** baseline (Xiong et al.): regional mesh +
//!   association rules + ARIMA, applied uniformly to all requests.
//! * [`streaming`] — subscription registry for the push/streaming
//!   mechanism (§IV-B).

pub mod arima;
pub mod assoc;
pub mod fpgrowth;
pub mod hybrid;
pub mod markov;
pub mod mesh;
pub mod streaming;

use crate::trace::{Request, StreamId, TimeRange, Trace, UserId};

/// Pre-fetch lead offset: fetch at `ts_i + OFFSET · (ts_pred − ts_i)`
/// (paper §IV-A2, empirically 0.8).
pub const PREFETCH_OFFSET: f64 = 0.8;

/// Max data objects pre-fetched per association-rule prediction
/// (paper §IV-A3, empirically 3).
pub const ASSOC_TOP_N: usize = 3;

/// A predicted future request to pre-fetch for.
#[derive(Debug, Clone, PartialEq)]
pub struct Prediction {
    pub user: UserId,
    pub stream: StreamId,
    /// Predicted observation-time range to stage.
    pub range: TimeRange,
    /// Simulated time at which to launch the pre-fetch transfer.
    pub fire_at: f64,
}

/// Actions a model can request from the push engine.
#[derive(Debug, Clone, PartialEq)]
pub enum Action {
    /// Stage data toward the user's DTN ahead of the predicted request.
    Prefetch(Prediction),
    /// Convert a real-time request series into a push subscription
    /// (streaming mechanism, §IV-B). Only HPM emits this.
    Subscribe {
        user: UserId,
        stream: StreamId,
        /// Smoothed request period (push cadence), seconds.
        period: f64,
    },
}

/// A pre-fetching model: observes the demand stream, emits actions.
pub trait PrefetchModel {
    /// Observe one demand request (fed in timestamp order); returns the
    /// actions to schedule.
    fn observe(&mut self, req: &Request, trace: &Trace) -> Vec<Action>;

    /// Periodic model rebuild (rule mining, transition re-estimation).
    fn rebuild(&mut self, now: f64);

    /// Display name (experiment tables).
    fn name(&self) -> &'static str;
}

/// The strategy axis of the evaluation grid (§V-B1).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Strategy {
    /// Direct observatory delivery (current practice).
    NoCache,
    /// DTN cache layer only, no prediction.
    CacheOnly,
    /// Framework + MD1 (Markov) pre-fetching.
    Md1,
    /// Framework + MD2 (mesh + rules + ARIMA) pre-fetching.
    Md2,
    /// Framework + the hybrid pre-fetching model.
    Hpm,
}

impl Strategy {
    pub const ALL: [Strategy; 5] = [
        Strategy::NoCache,
        Strategy::CacheOnly,
        Strategy::Md1,
        Strategy::Md2,
        Strategy::Hpm,
    ];

    pub fn name(&self) -> &'static str {
        match self {
            Strategy::NoCache => "No Cache",
            Strategy::CacheOnly => "Cache Only",
            Strategy::Md1 => "MD1",
            Strategy::Md2 => "MD2",
            Strategy::Hpm => "HPM",
        }
    }

    pub fn parse(s: &str) -> Option<Strategy> {
        match s.to_ascii_lowercase().replace([' ', '-', '_'], "").as_str() {
            "nocache" => Some(Strategy::NoCache),
            "cacheonly" | "cache" => Some(Strategy::CacheOnly),
            "md1" => Some(Strategy::Md1),
            "md2" => Some(Strategy::Md2),
            "hpm" => Some(Strategy::Hpm),
            _ => None,
        }
    }

    pub fn uses_cache(&self) -> bool {
        !matches!(self, Strategy::NoCache)
    }

    pub fn uses_prefetch(&self) -> bool {
        matches!(self, Strategy::Md1 | Strategy::Md2 | Strategy::Hpm)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn strategy_parse_roundtrip() {
        for s in Strategy::ALL {
            assert_eq!(Strategy::parse(s.name()), Some(s));
        }
        assert_eq!(Strategy::parse("hpm"), Some(Strategy::Hpm));
        assert_eq!(Strategy::parse("no-cache"), Some(Strategy::NoCache));
        assert_eq!(Strategy::parse("bogus"), None);
    }

    #[test]
    fn strategy_capabilities() {
        assert!(!Strategy::NoCache.uses_cache());
        assert!(Strategy::CacheOnly.uses_cache());
        assert!(!Strategy::CacheOnly.uses_prefetch());
        assert!(Strategy::Hpm.uses_prefetch());
    }
}
