//! Data pre-fetching models (paper §IV-A, §IV-B, §V-A2).
//!
//! * [`arima`] — next-gap forecasting (history-based prediction core).
//! * [`fpgrowth`] — FP-tree / FP-Growth frequent-itemset mining.
//! * [`assoc`] — association-rule prediction over data objects.
//! * [`hybrid`] — **HPM**, the paper's contribution: classifier-routed
//!   hybrid of history-based ARIMA (program users), association rules
//!   (human users) and streaming subscriptions (real-time users).
//! * [`markov`] — **MD1** baseline (Li et al.): Markov model over
//!   geospatial access paths.
//! * [`mesh`] — **MD2** baseline (Xiong et al.): regional mesh +
//!   association rules + ARIMA, applied uniformly to all requests.
//! * [`streaming`] — subscription registry for the push/streaming
//!   mechanism (§IV-B).

pub mod arima;
pub mod assoc;
pub mod fpgrowth;
pub mod hybrid;
pub mod markov;
pub mod mesh;
pub mod streaming;

use crate::trace::{Request, StreamId, TimeRange, Trace, UserId};
use crate::util::parse::{lookup, ParseError};

/// Pre-fetch lead offset: fetch at `ts_i + OFFSET · (ts_pred − ts_i)`
/// (paper §IV-A2, empirically 0.8).  Default for [`ModelKnobs::offset`].
pub const PREFETCH_OFFSET: f64 = 0.8;

/// Max data objects pre-fetched per association-rule prediction
/// (paper §IV-A3, empirically 3).  Default for [`ModelKnobs::top_n`].
pub const ASSOC_TOP_N: usize = 3;

/// Per-model tuning knobs shared by every pre-fetching model.  The
/// paper's empirical values are the defaults; the scenario API
/// ([`crate::scenario::ModelSpec`]) exposes both as sweepable axes.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ModelKnobs {
    /// Pre-fetch lead offset: fire at `ts_i + offset · (ts_pred − ts_i)`.
    pub offset: f64,
    /// Max objects pre-fetched per association/popularity prediction.
    pub top_n: usize,
}

impl Default for ModelKnobs {
    fn default() -> Self {
        Self {
            offset: PREFETCH_OFFSET,
            top_n: ASSOC_TOP_N,
        }
    }
}

/// A predicted future request to pre-fetch for.
#[derive(Debug, Clone, PartialEq)]
pub struct Prediction {
    pub user: UserId,
    pub stream: StreamId,
    /// Predicted observation-time range to stage.
    pub range: TimeRange,
    /// Simulated time at which to launch the pre-fetch transfer.
    pub fire_at: f64,
}

/// Actions a model can request from the push engine.
#[derive(Debug, Clone, PartialEq)]
pub enum Action {
    /// Stage data toward the user's DTN ahead of the predicted request.
    Prefetch(Prediction),
    /// Convert a real-time request series into a push subscription
    /// (streaming mechanism, §IV-B). Only HPM emits this.
    Subscribe {
        user: UserId,
        stream: StreamId,
        /// Smoothed request period (push cadence), seconds.
        period: f64,
    },
}

/// A pre-fetching model: observes the demand stream, emits actions.
pub trait PrefetchModel {
    /// Observe one demand request (fed in timestamp order); returns the
    /// actions to schedule.
    fn observe(&mut self, req: &Request, trace: &Trace) -> Vec<Action>;

    /// Periodic model rebuild (rule mining, transition re-estimation).
    fn rebuild(&mut self, now: f64);

    /// Display name (experiment tables).
    fn name(&self) -> &'static str;
}

/// The strategy axis of the evaluation grid (§V-B1).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Strategy {
    /// Direct observatory delivery (current practice).
    NoCache,
    /// DTN cache layer only, no prediction.
    CacheOnly,
    /// Framework + MD1 (Markov) pre-fetching.
    Md1,
    /// Framework + MD2 (mesh + rules + ARIMA) pre-fetching.
    Md2,
    /// Framework + the hybrid pre-fetching model.
    Hpm,
}

impl Strategy {
    pub const ALL: [Strategy; 5] = [
        Strategy::NoCache,
        Strategy::CacheOnly,
        Strategy::Md1,
        Strategy::Md2,
        Strategy::Hpm,
    ];

    pub fn name(&self) -> &'static str {
        match self {
            Strategy::NoCache => "No Cache",
            Strategy::CacheOnly => "Cache Only",
            Strategy::Md1 => "MD1",
            Strategy::Md2 => "MD2",
            Strategy::Hpm => "HPM",
        }
    }

    /// [`FromStr`](std::str::FromStr) as an `Option` (legacy signature;
    /// callers that want the alias-listing error use `s.parse()`).
    pub fn parse(s: &str) -> Option<Strategy> {
        s.parse().ok()
    }

    pub fn uses_cache(&self) -> bool {
        !matches!(self, Strategy::NoCache)
    }

    pub fn uses_prefetch(&self) -> bool {
        matches!(self, Strategy::Md1 | Strategy::Md2 | Strategy::Hpm)
    }
}

impl std::str::FromStr for Strategy {
    type Err = ParseError;

    /// Accepts the paper names and their documented aliases; the error
    /// for a bad value lists every accepted alias (`cache` is an
    /// explicit, documented alias of `cache-only`, not a silent one).
    fn from_str(s: &str) -> Result<Self, ParseError> {
        lookup(
            "strategy",
            s,
            &[
                (&["no-cache"], Strategy::NoCache),
                (&["cache-only", "cache"], Strategy::CacheOnly),
                (&["md1"], Strategy::Md1),
                (&["md2"], Strategy::Md2),
                (&["hpm"], Strategy::Hpm),
            ],
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn strategy_parse_roundtrip() {
        for s in Strategy::ALL {
            assert_eq!(Strategy::parse(s.name()), Some(s));
        }
        assert_eq!(Strategy::parse("hpm"), Some(Strategy::Hpm));
        assert_eq!(Strategy::parse("no-cache"), Some(Strategy::NoCache));
        assert_eq!(Strategy::parse("bogus"), None);
    }

    #[test]
    fn strategy_parse_error_lists_aliases() {
        let err = "bogus".parse::<Strategy>().unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("unknown strategy 'bogus'"), "{msg}");
        for alias in ["no-cache", "cache-only", "cache", "md1", "md2", "hpm"] {
            assert!(msg.contains(alias), "missing alias {alias} in: {msg}");
        }
    }

    #[test]
    fn model_knobs_default_to_paper_values() {
        let k = ModelKnobs::default();
        assert_eq!(k.offset, PREFETCH_OFFSET);
        assert_eq!(k.top_n, ASSOC_TOP_N);
    }

    #[test]
    fn strategy_capabilities() {
        assert!(!Strategy::NoCache.uses_cache());
        assert!(Strategy::CacheOnly.uses_cache());
        assert!(!Strategy::CacheOnly.uses_prefetch());
        assert!(Strategy::Hpm.uses_prefetch());
    }
}
