//! FP-Growth frequent-pattern mining (Han, Pei & Yin 2000), built from
//! scratch (paper §IV-A3a/b).
//!
//! Used by the association-rule prediction model: transactions are
//! browsing sessions (sets of data-object ids), the FP-tree compacts
//! them, and the recursive conditional-tree mining enumerates all
//! itemsets whose *support* (absolute transaction count) meets the
//! threshold.  Rule generation + the confidence filter live in
//! [`crate::prefetch::assoc`].

use std::collections::HashMap;

/// Item identifier (data-object / mesh-cell id).
pub type Item = u32;

/// A frequent itemset with its support count.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct FrequentSet {
    pub items: Vec<Item>, // sorted ascending
    pub support: u64,
}

#[derive(Debug)]
struct Node {
    item: Item,
    count: u64,
    parent: usize,
    children: HashMap<Item, usize>,
}

/// FP-tree with header table.
struct FpTree {
    nodes: Vec<Node>,
    /// item → node indices holding that item.
    header: HashMap<Item, Vec<usize>>,
}

impl FpTree {
    fn new() -> Self {
        Self {
            nodes: vec![Node {
                item: u32::MAX,
                count: 0,
                parent: usize::MAX,
                children: HashMap::new(),
            }],
            header: HashMap::new(),
        }
    }

    /// Insert a transaction (items already support-ordered) with count.
    fn insert(&mut self, items: &[Item], count: u64) {
        let mut cur = 0usize;
        for &item in items {
            let next = match self.nodes[cur].children.get(&item) {
                Some(&n) => {
                    self.nodes[n].count += count;
                    n
                }
                None => {
                    let n = self.nodes.len();
                    self.nodes.push(Node {
                        item,
                        count,
                        parent: cur,
                        children: HashMap::new(),
                    });
                    self.nodes[cur].children.insert(item, n);
                    self.header.entry(item).or_default().push(n);
                    n
                }
            };
            cur = next;
        }
    }

    /// Path from a node's parent up to the root (excluding root).
    fn prefix_path(&self, mut node: usize) -> Vec<Item> {
        let mut path = Vec::new();
        node = self.nodes[node].parent;
        while node != 0 && node != usize::MAX {
            path.push(self.nodes[node].item);
            node = self.nodes[node].parent;
        }
        path.reverse();
        path
    }
}

/// Mine all frequent itemsets with `support ≥ min_support` from
/// transactions.  Each transaction is a set (deduplicated internally).
pub fn mine(transactions: &[Vec<Item>], min_support: u64) -> Vec<FrequentSet> {
    // 1. Global item counts (1-itemset supports).
    let mut counts: HashMap<Item, u64> = HashMap::new();
    for t in transactions {
        let mut seen: Vec<Item> = t.clone();
        seen.sort_unstable();
        seen.dedup();
        for item in seen {
            *counts.entry(item).or_insert(0) += 1;
        }
    }
    counts.retain(|_, c| *c >= min_support);
    if counts.is_empty() {
        return Vec::new();
    }

    // 2. Build the FP-tree with items ordered by descending support
    //    (ties by item id for determinism).
    let order_key = |item: &Item| (std::cmp::Reverse(counts[item]), *item);
    let mut tree = FpTree::new();
    for t in transactions {
        let mut items: Vec<Item> = t
            .iter()
            .copied()
            .filter(|i| counts.contains_key(i))
            .collect();
        items.sort_unstable();
        items.dedup();
        items.sort_by_key(order_key);
        if !items.is_empty() {
            tree.insert(&items, 1);
        }
    }

    // 3. Recursive mining.
    let mut out = Vec::new();
    mine_tree(&tree, &[], min_support, &mut out);
    // Deterministic output order: by (len, items).
    out.sort_by(|a, b| (a.items.len(), &a.items).cmp(&(b.items.len(), &b.items)));
    out
}

fn mine_tree(tree: &FpTree, suffix: &[Item], min_support: u64, out: &mut Vec<FrequentSet>) {
    // Header items ordered ascending by support (mine least-frequent
    // first, the classic bottom-up order); ties by id.
    let mut items: Vec<(Item, u64)> = tree
        .header
        .iter()
        .map(|(&item, nodes)| (item, nodes.iter().map(|&n| tree.nodes[n].count).sum()))
        .collect();
    items.retain(|(_, s)| *s >= min_support);
    items.sort_by_key(|&(item, s)| (s, item));

    for (item, support) in items {
        let mut itemset = vec![item];
        itemset.extend_from_slice(suffix);
        itemset.sort_unstable();
        out.push(FrequentSet {
            items: itemset.clone(),
            support,
        });

        // Conditional pattern base for `item`.
        let mut cond_counts: HashMap<Item, u64> = HashMap::new();
        let paths: Vec<(Vec<Item>, u64)> = tree.header[&item]
            .iter()
            .map(|&n| (tree.prefix_path(n), tree.nodes[n].count))
            .collect();
        for (path, count) in &paths {
            for &i in path {
                *cond_counts.entry(i).or_insert(0) += count;
            }
        }
        cond_counts.retain(|_, c| *c >= min_support);
        if cond_counts.is_empty() {
            continue;
        }
        // Conditional FP-tree.
        let order_key = |i: &Item| (std::cmp::Reverse(cond_counts[i]), *i);
        let mut cond_tree = FpTree::new();
        for (path, count) in &paths {
            let mut p: Vec<Item> = path
                .iter()
                .copied()
                .filter(|i| cond_counts.contains_key(i))
                .collect();
            p.sort_by_key(order_key);
            if !p.is_empty() {
                cond_tree.insert(&p, *count);
            }
        }
        mine_tree(&cond_tree, &itemset, min_support, out);
    }
}

/// Brute-force miner for cross-checking FP-Growth in tests
/// (exponential; only safe for small item universes).
#[cfg(test)]
pub fn mine_bruteforce(transactions: &[Vec<Item>], min_support: u64) -> Vec<FrequentSet> {
    use std::collections::BTreeSet;
    let mut universe: BTreeSet<Item> = BTreeSet::new();
    for t in transactions {
        universe.extend(t.iter().copied());
    }
    let items: Vec<Item> = universe.into_iter().collect();
    assert!(items.len() <= 20, "universe too large for brute force");
    let sets: Vec<BTreeSet<Item>> = transactions
        .iter()
        .map(|t| t.iter().copied().collect())
        .collect();
    let mut out = Vec::new();
    for mask in 1u32..(1 << items.len()) {
        let subset: Vec<Item> = (0..items.len())
            .filter(|&i| mask & (1 << i) != 0)
            .map(|i| items[i])
            .collect();
        let support = sets
            .iter()
            .filter(|s| subset.iter().all(|i| s.contains(i)))
            .count() as u64;
        if support >= min_support {
            out.push(FrequentSet {
                items: subset,
                support,
            });
        }
    }
    out.sort_by(|a, b| (a.items.len(), &a.items).cmp(&(b.items.len(), &b.items)));
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn t(items: &[Item]) -> Vec<Item> {
        items.to_vec()
    }

    #[test]
    fn classic_example() {
        // Han et al. style example.
        let txs = vec![
            t(&[1, 2, 5]),
            t(&[2, 4]),
            t(&[2, 3]),
            t(&[1, 2, 4]),
            t(&[1, 3]),
            t(&[2, 3]),
            t(&[1, 3]),
            t(&[1, 2, 3, 5]),
            t(&[1, 2, 3]),
        ];
        let sets = mine(&txs, 2);
        let find = |items: &[Item]| {
            sets.iter()
                .find(|s| s.items == items)
                .map(|s| s.support)
        };
        assert_eq!(find(&[1]), Some(6));
        assert_eq!(find(&[2]), Some(7));
        assert_eq!(find(&[1, 2]), Some(4));
        assert_eq!(find(&[1, 2, 3]), Some(2));
        assert_eq!(find(&[1, 2, 5]), Some(2));
        assert_eq!(find(&[4]), Some(2));
        assert_eq!(find(&[5]), Some(2));
        assert_eq!(find(&[3, 5]), None); // support 1 < 2
    }

    #[test]
    fn empty_and_degenerate() {
        assert!(mine(&[], 1).is_empty());
        assert!(mine(&[vec![]], 1).is_empty());
        let sets = mine(&[t(&[7])], 1);
        assert_eq!(sets.len(), 1);
        assert_eq!(sets[0].support, 1);
    }

    #[test]
    fn min_support_filters() {
        let txs = vec![t(&[1, 2]), t(&[1]), t(&[1])];
        let sets = mine(&txs, 3);
        assert_eq!(sets.len(), 1);
        assert_eq!(sets[0].items, vec![1]);
    }

    #[test]
    fn duplicate_items_in_transaction_count_once() {
        let txs = vec![t(&[1, 1, 1]), t(&[1])];
        let sets = mine(&txs, 2);
        assert_eq!(sets[0].support, 2);
    }

    #[test]
    fn matches_bruteforce_small_random() {
        crate::util::prop::check("fpgrowth-vs-bruteforce", |rng| {
            let n_items = rng.int_range(3, 9);
            let n_tx = rng.int_range(5, 30);
            let txs: Vec<Vec<Item>> = (0..n_tx)
                .map(|_| {
                    let len = rng.int_range(1, n_items + 1);
                    rng.sample_indices(n_items, len)
                        .into_iter()
                        .map(|i| i as Item)
                        .collect()
                })
                .collect();
            let minsup = rng.int_range(1, 5) as u64;
            let got = mine(&txs, minsup);
            let want = mine_bruteforce(&txs, minsup);
            assert_eq!(got, want, "txs={txs:?} minsup={minsup}");
        });
    }
}
