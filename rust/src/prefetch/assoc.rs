//! Association-rule prediction model (paper §IV-A3).
//!
//! Sessionizes each user's request stream into transactions (sets of
//! data objects), mines frequent itemsets with FP-Growth, generates
//! rules `X → y` filtered by *confidence*, and predicts the next data
//! objects for a user from the rules matching their current session.
//! The paper empirically sets support = 30 and confidence = 0.5, and
//! pre-fetches only the top-3 predicted objects; support scales with
//! the (scaled-down) synthetic traces via [`AssocConfig::min_support`].

use std::collections::HashMap;

use crate::prefetch::fpgrowth::{self, Item};

/// Rule `antecedent → consequent` with confidence.
#[derive(Debug, Clone, PartialEq)]
pub struct Rule {
    pub antecedent: Vec<Item>, // sorted
    pub consequent: Item,
    pub confidence: f64,
    pub support: u64,
}

/// Tunables (paper defaults scaled to trace size).
#[derive(Debug, Clone)]
pub struct AssocConfig {
    /// Absolute minimum itemset support (paper: 30).
    pub min_support: u64,
    /// Minimum rule confidence (paper: 0.5).
    pub min_confidence: f64,
    /// Session idle gap: a new transaction starts after this silence.
    pub session_gap_secs: f64,
    /// Cap on retained training transactions (sliding window).
    pub max_transactions: usize,
}

impl Default for AssocConfig {
    fn default() -> Self {
        Self {
            min_support: 5,
            min_confidence: 0.5,
            session_gap_secs: 1800.0,
            max_transactions: 20_000,
        }
    }
}

/// Online transaction collector + rule miner.
pub struct AssocModel {
    cfg: AssocConfig,
    /// Completed transactions (training window).
    transactions: Vec<Vec<Item>>,
    /// Per-user open session: (last ts, items).
    open: HashMap<u32, (f64, Vec<Item>)>,
    /// Mined rules, indexed by each antecedent item for fast matching.
    rules: Vec<Rule>,
    by_item: HashMap<Item, Vec<usize>>,
    /// Generation-stamped dedup scratch (one slot per rule) — keeps
    /// `predict` allocation- and sort-free on the hot path.
    stamp: Vec<u32>,
    generation: u32,
    /// Rules rebuilt at least once.
    pub built: bool,
}

impl AssocModel {
    pub fn new(cfg: AssocConfig) -> Self {
        Self {
            cfg,
            transactions: Vec::new(),
            open: HashMap::new(),
            rules: Vec::new(),
            by_item: HashMap::new(),
            stamp: Vec::new(),
            generation: 0,
            built: false,
        }
    }

    /// Observe one request; closes the user's session if it went idle.
    pub fn observe(&mut self, user: u32, item: Item, ts: f64) {
        let entry = self.open.entry(user).or_insert_with(|| (ts, Vec::new()));
        if ts - entry.0 > self.cfg.session_gap_secs && !entry.1.is_empty() {
            let items = std::mem::take(&mut entry.1);
            Self::push_tx(&mut self.transactions, self.cfg.max_transactions, items);
        }
        entry.0 = ts;
        if !entry.1.contains(&item) {
            entry.1.push(item);
        }
    }

    fn push_tx(txs: &mut Vec<Vec<Item>>, cap: usize, items: Vec<Item>) {
        if items.len() >= 2 {
            txs.push(items);
            if txs.len() > cap {
                let excess = txs.len() - cap;
                txs.drain(..excess);
            }
        }
    }

    /// The user's current (open) session items.
    pub fn session_items(&self, user: u32) -> &[Item] {
        self.open
            .get(&user)
            .map(|(_, items)| items.as_slice())
            .unwrap_or(&[])
    }

    /// Mine rules from the training window (FP-Growth + confidence
    /// filter).  Call periodically (paper: the model is retrained as
    /// the framework runs).  Open sessions are included as snapshot
    /// transactions so recent activity contributes to the rules.
    pub fn rebuild(&mut self) {
        let mut training = self.transactions.clone();
        // Snapshot open sessions in user order: the training list's
        // order must not depend on HashMap layout (future caps or
        // sampling over it would otherwise be nondeterministic).
        let mut snapshots: Vec<(u32, &Vec<Item>)> =
            self.open.iter().map(|(&u, (_, items))| (u, items)).collect();
        snapshots.sort_unstable_by_key(|&(u, _)| u);
        for (_, items) in snapshots {
            if items.len() >= 2 {
                training.push(items.clone());
            }
        }
        let sets = fpgrowth::mine(&training, self.cfg.min_support);
        // Support lookup for confidence computation.
        let sup: HashMap<&[Item], u64> =
            sets.iter().map(|s| (s.items.as_slice(), s.support)).collect();
        self.rules.clear();
        self.by_item.clear();
        for set in &sets {
            if set.items.len() < 2 {
                continue;
            }
            // Single-consequent rules: X \ {y} → y.
            for (i, &y) in set.items.iter().enumerate() {
                let mut ante = set.items.clone();
                ante.remove(i);
                let Some(&ante_sup) = sup.get(ante.as_slice()) else {
                    continue;
                };
                let confidence = set.support as f64 / ante_sup as f64;
                if confidence >= self.cfg.min_confidence {
                    let idx = self.rules.len();
                    for &a in &ante {
                        self.by_item.entry(a).or_default().push(idx);
                    }
                    self.rules.push(Rule {
                        antecedent: ante,
                        consequent: y,
                        confidence,
                        support: set.support,
                    });
                }
            }
        }
        self.stamp = vec![0; self.rules.len()];
        self.generation = 0;
        self.built = true;
    }

    /// Predict up to `top_n` next objects for a session's items, ranked
    /// by rule confidence (then support).  Items already in the session
    /// are not re-predicted.
    pub fn predict(&mut self, session: &[Item], top_n: usize) -> Vec<Item> {
        let mut best: HashMap<Item, (f64, u64)> = HashMap::new();
        // Generation-stamped visit set: each rule index is evaluated at
        // most once per call without sorting or allocating.
        self.generation = self.generation.wrapping_add(1);
        if self.generation == 0 {
            self.stamp.iter_mut().for_each(|s| *s = 0);
            self.generation = 1;
        }
        let generation = self.generation;
        for item in session {
            let Some(rule_ids) = self.by_item.get(item) else {
                continue;
            };
            for &idx in rule_ids {
                if self.stamp[idx] == generation {
                    continue;
                }
                self.stamp[idx] = generation;
                let rule = &self.rules[idx];
                if session.contains(&rule.consequent) {
                    continue;
                }
                // Antecedent must be fully contained in the session.
                if rule.antecedent.iter().all(|a| session.contains(a)) {
                    let e = best
                        .entry(rule.consequent)
                        .or_insert((rule.confidence, rule.support));
                    if rule.confidence > e.0 || (rule.confidence == e.0 && rule.support > e.1) {
                        *e = (rule.confidence, rule.support);
                    }
                }
            }
        }
        let mut ranked: Vec<(Item, (f64, u64))> = best.into_iter().collect();
        ranked.sort_by(|a, b| {
            b.1 .0
                .total_cmp(&a.1 .0)
                .then(b.1 .1.cmp(&a.1 .1))
                .then(a.0.cmp(&b.0))
        });
        ranked.into_iter().take(top_n).map(|(i, _)| i).collect()
    }

    pub fn n_rules(&self) -> usize {
        self.rules.len()
    }

    pub fn n_transactions(&self) -> usize {
        self.transactions.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn model_with_pattern() -> AssocModel {
        let mut m = AssocModel::new(AssocConfig {
            min_support: 3,
            min_confidence: 0.5,
            session_gap_secs: 100.0,
            max_transactions: 1000,
        });
        // 10 users each browse {1, 2, 3} together; a few also touch 9.
        let mut ts = 0.0;
        for u in 0..10 {
            for &item in &[1u32, 2, 3] {
                m.observe(u, item, ts);
                ts += 1.0;
            }
            ts += 1000.0; // close session on next observe
        }
        // Force-close all sessions by observing far in the future.
        for u in 0..10 {
            m.observe(u, 99, ts + 1e6);
        }
        m.rebuild();
        m
    }

    #[test]
    fn mines_rules_from_sessions() {
        let m = model_with_pattern();
        assert!(m.n_transactions() >= 10);
        assert!(m.n_rules() > 0);
    }

    #[test]
    fn predicts_co_browsed_objects() {
        let mut m = model_with_pattern();
        let pred = m.predict(&[1, 2], 3);
        assert_eq!(pred.first(), Some(&3), "pred={pred:?}");
    }

    #[test]
    fn does_not_predict_session_items() {
        let mut m = model_with_pattern();
        let pred = m.predict(&[1, 2, 3], 3);
        assert!(!pred.contains(&1) && !pred.contains(&2) && !pred.contains(&3));
    }

    #[test]
    fn empty_session_predicts_nothing() {
        let mut m = model_with_pattern();
        assert!(m.predict(&[], 3).is_empty());
    }

    #[test]
    fn top_n_respected() {
        let mut m = AssocModel::new(AssocConfig {
            min_support: 2,
            min_confidence: 0.3,
            session_gap_secs: 100.0,
            max_transactions: 1000,
        });
        let mut ts = 0.0;
        // Item 0 co-occurs with many others.
        for u in 0..8 {
            for item in [0u32, 1, 2, 3, 4, 5] {
                m.observe(u, item, ts);
                ts += 1.0;
            }
            ts += 1000.0;
        }
        for u in 0..8 {
            m.observe(u, 99, ts + 1e6);
        }
        m.rebuild();
        assert!(m.predict(&[0], 3).len() <= 3);
        assert!(m.predict(&[0], 1).len() <= 1);
    }

    #[test]
    fn confidence_filter_drops_weak_rules() {
        let mut strict = AssocModel::new(AssocConfig {
            min_support: 2,
            min_confidence: 0.99,
            session_gap_secs: 100.0,
            max_transactions: 1000,
        });
        let mut ts = 0.0;
        // 1 → 2 holds half the time only.
        for u in 0..10 {
            strict.observe(u, 1, ts);
            if u % 2 == 0 {
                strict.observe(u, 2, ts + 1.0);
            } else {
                strict.observe(u, 3, ts + 1.0);
            }
            ts += 1000.0;
        }
        for u in 0..10 {
            strict.observe(u, 99, ts + 1e6);
        }
        strict.rebuild();
        assert!(strict.predict(&[1], 3).is_empty());
    }

    /// Regression: `predict` ranks candidates out of a `HashMap`, so
    /// ties on (confidence, support) must fall through to the item id —
    /// the pre-fix sort had no final key and returned hash-order-
    /// dependent prefixes under `top_n` truncation.
    #[test]
    fn tied_predictions_rank_by_item_id() {
        let mut m = AssocModel::new(AssocConfig {
            min_support: 2,
            min_confidence: 0.3,
            session_gap_secs: 100.0,
            max_transactions: 1000,
        });
        // Items 4/7/2/9 all co-occur with 0 in every session: identical
        // confidence and support for each 0 → y rule.
        let mut ts = 0.0;
        for u in 0..6 {
            for item in [0u32, 4, 7, 2, 9] {
                m.observe(u, item, ts);
                ts += 1.0;
            }
            ts += 1000.0;
        }
        for u in 0..6 {
            m.observe(u, 99, ts + 1e6);
        }
        m.rebuild();
        let full = m.predict(&[0], 10);
        assert_eq!(full, vec![2, 4, 7, 9], "tie must break on item id");
        // Truncation takes a prefix of the same deterministic order.
        assert_eq!(m.predict(&[0], 2), vec![2, 4]);
    }

    #[test]
    fn sliding_window_caps_memory() {
        let mut m = AssocModel::new(AssocConfig {
            min_support: 2,
            min_confidence: 0.5,
            session_gap_secs: 10.0,
            max_transactions: 5,
        });
        let mut ts = 0.0;
        for i in 0..50 {
            m.observe(0, i % 7, ts);
            m.observe(0, (i + 1) % 7, ts + 1.0);
            ts += 100.0; // close previous session each time
        }
        assert!(m.n_transactions() <= 5);
    }
}
