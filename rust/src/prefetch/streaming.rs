//! Streaming mechanism for real-time requests (paper §IV-B).
//!
//! Real-time monitoring is implemented by users as high-frequency
//! pull-based polling (Fig. 3b), which floods the observatory with
//! small requests.  The framework converts a detected real-time series
//! into a *subscription*: the server-side DTN pushes each newly
//! available chunk toward the subscriber's DTN, duplicate requests
//! from co-located subscribers are coalesced (one push per (stream,
//! DTN, chunk)), and the subscription expires when the user stops
//! requesting.

use std::collections::HashMap;

use crate::trace::{StreamId, UserId};

/// Subscription expiry: if no demand request is seen for this many
/// periods, pushing stops.
pub const EXPIRY_PERIODS: f64 = 10.0;

/// One active subscription.
#[derive(Debug, Clone)]
pub struct Subscription {
    pub user: UserId,
    pub stream: StreamId,
    /// Client DTN the user is attached to (push destination).
    pub dtn: usize,
    /// Push cadence (smoothed request period, from stream_stats).
    pub period: f64,
    /// Last time the user actually demanded this stream.
    pub last_demand: f64,
    /// Next observation-time chunk index to push.
    pub next_chunk: u64,
}

impl Subscription {
    pub fn expired(&self, now: f64) -> bool {
        now - self.last_demand > EXPIRY_PERIODS * self.period
    }
}

/// Registry of active subscriptions.
#[derive(Debug, Default)]
pub struct StreamRegistry {
    subs: HashMap<(UserId, StreamId), Subscription>,
    /// Lifetime counters (metrics).
    pub pushes: u64,
    pub coalesced: u64,
}

impl StreamRegistry {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn len(&self) -> usize {
        self.subs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.subs.is_empty()
    }

    pub fn contains(&self, user: UserId, stream: StreamId) -> bool {
        self.subs.contains_key(&(user, stream))
    }

    /// Register (or refresh) a subscription. Returns true if new —
    /// the caller schedules the first push event for new subscriptions.
    pub fn subscribe(
        &mut self,
        user: UserId,
        stream: StreamId,
        dtn: usize,
        period: f64,
        now: f64,
        chunk_secs: f64,
    ) -> bool {
        let key = (user, stream);
        let is_new = !self.subs.contains_key(&key);
        let next_chunk = (now / chunk_secs).floor() as u64;
        let e = self.subs.entry(key).or_insert(Subscription {
            user,
            stream,
            dtn,
            period,
            last_demand: now,
            next_chunk,
        });
        e.period = period;
        e.last_demand = now;
        is_new
    }

    /// Renew on a demand request (keeps the subscription alive).
    pub fn on_demand(&mut self, user: UserId, stream: StreamId, now: f64) {
        if let Some(s) = self.subs.get_mut(&(user, stream)) {
            s.last_demand = now;
        }
    }

    pub fn get(&self, user: UserId, stream: StreamId) -> Option<&Subscription> {
        self.subs.get(&(user, stream))
    }

    /// Process one push tick for a subscription.  Returns the chunks
    /// that became available since the last push (to be transferred to
    /// the subscriber's DTN), or `None` if the subscription expired and
    /// was removed.  `now` is observation == wall time (live data).
    pub fn push_tick(
        &mut self,
        user: UserId,
        stream: StreamId,
        now: f64,
        chunk_secs: f64,
    ) -> Option<std::ops::Range<u64>> {
        let key = (user, stream);
        let expired = match self.subs.get(&key) {
            None => return None,
            Some(s) => s.expired(now),
        };
        if expired {
            self.subs.remove(&key);
            return None;
        }
        let s = self.subs.get_mut(&key).unwrap();
        // Chunks *published* (closed) by `now` — the observatory
        // publishes data in chunk-granular batches (§III-D), and the
        // push engine ships each batch the moment it closes.
        let avail_end = (now / chunk_secs).floor() as u64;
        let range = s.next_chunk..avail_end.max(s.next_chunk);
        s.next_chunk = range.end;
        self.pushes += 1;
        Some(range)
    }

    /// All live subscriptions in ascending (user, stream) order
    /// (placement / diagnostics).  Sorted so the exposure order is a
    /// function of the registry contents, never of HashMap layout.
    pub fn iter(&self) -> impl Iterator<Item = &Subscription> {
        let mut live: Vec<&Subscription> = self.subs.values().collect();
        live.sort_unstable_by_key(|s| (s.user, s.stream));
        live.into_iter()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const CHUNK: f64 = 100.0;

    #[test]
    fn subscribe_then_push_yields_new_chunks() {
        let mut reg = StreamRegistry::new();
        let is_new = reg.subscribe(UserId(1), StreamId(2), 3, 60.0, 1000.0, CHUNK);
        assert!(is_new);
        // At t=1000, next_chunk = 10. By t=1250, chunks 10..12 closed.
        let r = reg.push_tick(UserId(1), StreamId(2), 1250.0, CHUNK).unwrap();
        assert_eq!(r, 10..12);
        // Nothing new yet at 1299.
        let r2 = reg.push_tick(UserId(1), StreamId(2), 1299.0, CHUNK).unwrap();
        assert!(r2.is_empty());
        // Chunk 12 closes at 1300.
        let r3 = reg.push_tick(UserId(1), StreamId(2), 1310.0, CHUNK).unwrap();
        assert_eq!(r3, 12..13);
    }

    #[test]
    fn resubscribe_is_not_new() {
        let mut reg = StreamRegistry::new();
        assert!(reg.subscribe(UserId(1), StreamId(2), 3, 60.0, 0.0, CHUNK));
        assert!(!reg.subscribe(UserId(1), StreamId(2), 3, 55.0, 100.0, CHUNK));
        assert_eq!(reg.len(), 1);
        assert!((reg.get(UserId(1), StreamId(2)).unwrap().period - 55.0).abs() < 1e-12);
    }

    #[test]
    fn expires_without_demand() {
        let mut reg = StreamRegistry::new();
        reg.subscribe(UserId(1), StreamId(2), 3, 60.0, 0.0, CHUNK);
        // 10 periods of silence → expired.
        let r = reg.push_tick(UserId(1), StreamId(2), 601.0, CHUNK);
        assert!(r.is_none());
        assert!(reg.is_empty());
    }

    #[test]
    fn demand_renews_subscription() {
        let mut reg = StreamRegistry::new();
        reg.subscribe(UserId(1), StreamId(2), 3, 60.0, 0.0, CHUNK);
        reg.on_demand(UserId(1), StreamId(2), 580.0);
        // Was due to expire at 600 without the renewal.
        assert!(reg.push_tick(UserId(1), StreamId(2), 700.0, CHUNK).is_some());
    }

    #[test]
    fn push_tick_on_unknown_sub_is_none() {
        let mut reg = StreamRegistry::new();
        assert!(reg.push_tick(UserId(9), StreamId(9), 0.0, CHUNK).is_none());
    }

    /// Regression: `iter()` must yield ascending (user, stream) order
    /// whatever the subscription order — it used to expose raw
    /// `HashMap::values` order, leaking per-process hash layout to any
    /// future consumer.
    #[test]
    fn iter_is_sorted_by_user_then_stream() {
        let mut reg = StreamRegistry::new();
        for (u, st) in [(5u32, 1u32), (1, 9), (5, 0), (2, 4), (1, 2)] {
            reg.subscribe(UserId(u), StreamId(st), 0, 60.0, 0.0, CHUNK);
        }
        let order: Vec<(u32, u32)> = reg.iter().map(|s| (s.user.0, s.stream.0)).collect();
        assert_eq!(order, vec![(1, 2), (1, 9), (2, 4), (5, 0), (5, 1)]);
    }

    #[test]
    fn chunks_never_repushed() {
        let mut reg = StreamRegistry::new();
        reg.subscribe(UserId(1), StreamId(2), 3, 60.0, 0.0, CHUNK);
        let mut pushed = Vec::new();
        for t in [150.0, 250.0, 250.0, 400.0] {
            reg.on_demand(UserId(1), StreamId(2), t);
            if let Some(r) = reg.push_tick(UserId(1), StreamId(2), t, CHUNK) {
                pushed.extend(r);
            }
        }
        let mut dedup = pushed.clone();
        dedup.sort_unstable();
        dedup.dedup();
        assert_eq!(pushed, dedup, "chunk pushed twice: {pushed:?}");
    }
}
