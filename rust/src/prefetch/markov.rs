//! MD1 reference model: Markov prediction over geospatial access paths
//! (Li et al., "A prefetching model based on access popularity for
//! geospatial data in a cluster-based caching system", paper §V-A2).
//!
//! The authors connect the geospatial coordinates of consecutive
//! accesses into an "access path" and observe the paths follow Zipf's
//! law, so a first-order Markov chain over locations predicts the next
//! access.  Our implementation: states are instrument *sites*;
//! transition counts are learned online; the predicted next site's most
//! popular streams are pre-fetched with the user's last time range.
//! The model treats every request identically — no user classification
//! — which is exactly the weakness HPM exploits (§V-B1).

use std::collections::HashMap;

use crate::prefetch::{Action, ModelKnobs, Prediction, PrefetchModel};
use crate::trace::{Request, SiteId, StreamId, TimeRange, Trace, UserId};

/// First-order Markov chain over sites + per-site stream popularity.
#[derive(Debug, Default)]
pub struct MarkovModel {
    /// Lead offset + prediction width ([`ModelKnobs::default`] is the
    /// paper configuration; the scenario API sweeps both).
    knobs: ModelKnobs,
    /// site → (next site → count).
    transitions: HashMap<SiteId, HashMap<SiteId, u64>>,
    /// site → (stream → popularity count).
    popularity: HashMap<SiteId, HashMap<StreamId, u64>>,
    /// user → (last ts, last site, last range).
    last: HashMap<UserId, (f64, SiteId, TimeRange)>,
}

impl MarkovModel {
    pub fn new() -> Self {
        Self::with_knobs(ModelKnobs::default())
    }

    pub fn with_knobs(knobs: ModelKnobs) -> Self {
        Self {
            knobs,
            ..Self::default()
        }
    }

    /// Most likely next site from `site` (ties → smaller id, stable).
    pub fn predict_site(&self, site: SiteId) -> Option<SiteId> {
        self.transitions.get(&site).and_then(|m| {
            m.iter()
                .max_by_key(|(s, c)| (**c, std::cmp::Reverse(s.0)))
                .map(|(s, _)| *s)
        })
    }

    /// Top streams at a site by popularity.
    pub fn top_streams(&self, site: SiteId, n: usize) -> Vec<StreamId> {
        let Some(pop) = self.popularity.get(&site) else {
            return Vec::new();
        };
        let mut v: Vec<(StreamId, u64)> = pop.iter().map(|(s, c)| (*s, *c)).collect();
        v.sort_by_key(|(s, c)| (std::cmp::Reverse(*c), s.0));
        v.into_iter().take(n).map(|(s, _)| s).collect()
    }
}

impl PrefetchModel for MarkovModel {
    fn observe(&mut self, req: &Request, trace: &Trace) -> Vec<Action> {
        let site = trace.stream(req.stream).site;
        *self
            .popularity
            .entry(site)
            .or_default()
            .entry(req.stream)
            .or_insert(0) += 1;

        let prev = self.last.insert(req.user, (req.ts, site, req.range));
        let Some((prev_ts, prev_site, _)) = prev else {
            return Vec::new();
        };
        if prev_site != site {
            *self
                .transitions
                .entry(prev_site)
                .or_default()
                .entry(site)
                .or_insert(0) += 1;
        }

        // Predict the next site and pre-fetch its popular streams.
        let Some(next_site) = self.predict_site(site) else {
            return Vec::new();
        };
        let gap = (req.ts - prev_ts).max(1.0);
        let fire_at = req.ts + self.knobs.offset * gap;
        // Popularity-based scheme: pre-fetches the popular objects of
        // the predicted region over the *observed* time range — unlike
        // MD2, it has no temporal model to advance the window, which is
        // exactly why its recall trails (paper §V-B1).
        let range = req.range;
        self.top_streams(next_site, self.knobs.top_n)
            .into_iter()
            .map(|stream| {
                Action::Prefetch(Prediction {
                    user: req.user,
                    stream,
                    range,
                    fire_at,
                })
            })
            .collect()
    }

    fn rebuild(&mut self, _now: f64) {
        // Transition counts are maintained online; nothing to rebuild.
    }

    fn name(&self) -> &'static str {
        "MD1"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::{generator, presets};

    fn mk_trace() -> Trace {
        generator::generate(&presets::tiny())
    }

    fn req(trace: &Trace, user: u32, ts: f64, stream: u32) -> Request {
        Request {
            user: UserId(user),
            ts,
            stream: StreamId(stream % trace.streams.len() as u32),
            range: TimeRange::new(ts - 100.0, ts),
        }
    }

    #[test]
    fn learns_transitions() {
        let trace = mk_trace();
        let mut m = MarkovModel::new();
        // Find two streams at different sites.
        let s0 = 0u32;
        let s1 = trace
            .streams
            .iter()
            .position(|s| s.site != trace.streams[0].site)
            .unwrap() as u32;
        // User ping-pongs between the two sites.
        for i in 0..10 {
            m.observe(&req(&trace, 1, i as f64 * 100.0, if i % 2 == 0 { s0 } else { s1 }), &trace);
        }
        let site0 = trace.stream(StreamId(s0)).site;
        let site1 = trace.stream(StreamId(s1)).site;
        assert_eq!(m.predict_site(site0), Some(site1));
        assert_eq!(m.predict_site(site1), Some(site0));
    }

    #[test]
    fn emits_prefetch_after_transition_learned() {
        let trace = mk_trace();
        let mut m = MarkovModel::new();
        let s0 = 0u32;
        let s1 = trace
            .streams
            .iter()
            .position(|s| s.site != trace.streams[0].site)
            .unwrap() as u32;
        let mut actions = Vec::new();
        for i in 0..10 {
            actions = m.observe(
                &req(&trace, 1, i as f64 * 100.0, if i % 2 == 0 { s0 } else { s1 }),
                &trace,
            );
        }
        assert!(!actions.is_empty());
        match &actions[0] {
            Action::Prefetch(p) => {
                assert_eq!(p.user, UserId(1));
                // fire_at is offset into the predicted gap.
                assert!((p.fire_at - (900.0 + 0.8 * 100.0)).abs() < 1e-9);
            }
            other => panic!("expected prefetch, got {other:?}"),
        }
    }

    #[test]
    fn first_request_emits_nothing() {
        let trace = mk_trace();
        let mut m = MarkovModel::new();
        assert!(m.observe(&req(&trace, 1, 0.0, 0), &trace).is_empty());
    }

    #[test]
    fn popularity_ranks_streams() {
        let trace = mk_trace();
        let mut m = MarkovModel::new();
        // Two streams at the same site: find them.
        let site = trace.streams[0].site;
        let same_site: Vec<u32> = trace
            .streams
            .iter()
            .filter(|s| s.site == site)
            .map(|s| s.id.0)
            .collect();
        if same_site.len() < 2 {
            return; // preset didn't give co-located streams; skip
        }
        for _ in 0..5 {
            m.observe(&req(&trace, 2, 0.0, same_site[0]), &trace);
        }
        m.observe(&req(&trace, 2, 1.0, same_site[1]), &trace);
        let top = m.top_streams(site, 2);
        assert_eq!(top[0], StreamId(same_site[0]));
    }

    #[test]
    fn never_subscribes() {
        // MD1 has no streaming mechanism.
        let trace = mk_trace();
        let mut m = MarkovModel::new();
        for i in 0..50 {
            let acts = m.observe(&req(&trace, 3, i as f64 * 60.0, 0), &trace);
            assert!(acts
                .iter()
                .all(|a| matches!(a, Action::Prefetch(_))));
        }
    }
}
