//! Quickstart: generate a small synthetic observatory trace, run the
//! push-based delivery framework against the No-Cache baseline through
//! the scenario API, and print the headline metrics.
//!
//! ```sh
//! cargo run --release --example quickstart
//! ```

use obsd::cache::policy::PolicyKind;
use obsd::prefetch::Strategy;
use obsd::scenario::{Runner, Scenario};
use obsd::trace::{generator, presets};

fn main() {
    // 1. A small OOI-like trace: ~40 users, one day of requests.
    let preset = presets::tiny();
    let trace = generator::generate(&preset);
    println!(
        "trace: {} streams, {} users, {} requests over {:.1} h",
        trace.streams.len(),
        trace.users.len(),
        trace.requests.len(),
        trace.duration / 3600.0
    );

    // 2. Run the baseline and the framework: two preset points of the
    //    composable scenario space (delivery × model × cache × ...).
    let runner = Runner::new();
    let base_sc = Scenario::preset(Strategy::NoCache);
    let mut hpm_sc = Scenario::preset(Strategy::Hpm);
    hpm_sc.policy = PolicyKind::Lru;
    hpm_sc.cache_bytes = 2 << 30; // 2 GB per client DTN
    let base = runner.run_trace(&trace, &base_sc).metrics;
    let hpm = runner.run_trace(&trace, &hpm_sc).metrics;

    // 3. Compare.
    println!("\n                         No Cache        HPM framework");
    println!(
        "throughput (Mbps)    {:>12.2} {:>17.2}",
        base.throughput_mbps(),
        hpm.throughput_mbps()
    );
    println!(
        "queue latency (s)    {:>12.4} {:>17.4}",
        base.latency_secs(),
        hpm.latency_secs()
    );
    println!(
        "origin requests      {:>12.1}% {:>16.1}%",
        base.origin_fraction() * 100.0,
        hpm.origin_fraction() * 100.0
    );
    println!(
        "origin traffic       {:>12} {:>17}",
        obsd::util::fmt_bytes(base.origin_bytes),
        obsd::util::fmt_bytes(hpm.origin_bytes)
    );
    let (c, p) = hpm.local_fractions();
    println!(
        "\nHPM served {:.1}% of requests from the user's local DTN
  ({:.1}% previously cached + {:.1}% proactively pre-fetched/streamed),
  with pre-fetch recall {:.2}.",
        (c + p) * 100.0,
        c * 100.0,
        p * 100.0,
        hpm.recall
    );
    println!(
        "speedup vs current delivery: {:.0}x throughput, {:.1}% origin-traffic reduction",
        hpm.throughput_mbps() / base.throughput_mbps().max(1e-9),
        hpm.traffic_reduction_vs(base.origin_bytes) * 100.0
    );
}
