//! End-to-end driver: the full three-layer system on the OOI workload.
//!
//! This is the repository's headline validation run (DESIGN.md §4
//! "headline"): it generates the calibrated OOI trace (≈700 k requests
//! over a simulated week), loads the **AOT-compiled JAX/Pallas
//! prediction models** through the PJRT CPU client, and replays the
//! trace through the coordinator for every strategy of the evaluation
//! grid — proving L1 (Pallas kernels) → L2 (JAX models) → L3 (Rust
//! coordinator) compose on a real workload.  Falls back to the
//! pure-Rust predictors with a warning if `make artifacts` hasn't run.
//!
//! ```sh
//! make artifacts && cargo run --release --example ooi_e2e
//! ```

use obsd::cache::policy::PolicyKind;
use obsd::prefetch::arima::GapPredictor;
use obsd::prefetch::Strategy;
use obsd::runtime::{artifacts_available, Engine};
use obsd::scenario::{Runner, Scenario};
use obsd::trace::{generator, presets};
use obsd::util::table::Table;

fn main() {
    #[allow(clippy::disallowed_methods)]
    let t_start = std::time::Instant::now(); // simlint: allow(D003): demo reports its own elapsed wall time
    println!("== OOI end-to-end: three-layer stack on the full preset ==\n");

    // Layer-3 workload.
    let trace = generator::generate(&presets::ooi());
    println!(
        "trace: {} streams, {} users, {} requests over {:.0} days ({} unique data)",
        trace.streams.len(),
        trace.users.len(),
        trace.requests.len(),
        trace.duration / 86_400.0,
        obsd::util::fmt_bytes(
            trace.streams.iter().map(|s| s.byte_rate * trace.duration).sum::<f64>()
        )
    );

    // Layers 1+2, AOT-compiled and loaded through PJRT.
    let use_pjrt = artifacts_available();
    if use_pjrt {
        println!("prediction models: AOT JAX/Pallas artifacts via PJRT CPU client");
    } else {
        println!("WARNING: artifacts/ missing (run `make artifacts`) — pure-Rust fallback");
    }

    let scenario = |strategy| {
        let mut sc = Scenario::preset(strategy);
        sc.policy = PolicyKind::Lru;
        sc.cache_bytes = 4 << 30;
        sc
    };
    // One runner serves the whole grid: the predictor factory is lazy,
    // so the PJRT engine is only loaded (once per run, compile time
    // excluded from the simulated metrics — the Wall column) for the
    // cells whose model consumes a gap predictor (MD2, HPM).
    let runner = if use_pjrt {
        Runner::new().with_predictor(|| -> Box<dyn GapPredictor> {
            Box::new(Engine::load_default().expect("artifact load"))
        })
    } else {
        Runner::new()
    };

    let mut table = Table::new("OOI end-to-end results (LRU, 4 GB/DTN, best network)").header(&[
        "Strategy",
        "Throughput (Mbps)",
        "Queue latency (s)",
        "Origin req %",
        "Origin traffic",
        "Recall",
        "Wall (s)",
    ]);
    let mut baseline_bytes = 0.0;
    let mut baseline_thrpt = 0.0;
    let mut hpm_summary = None;
    for strategy in Strategy::ALL {
        let sc = scenario(strategy);
        let m = runner.run_trace(&trace, &sc).metrics;
        if strategy == Strategy::NoCache {
            baseline_bytes = m.origin_bytes;
            baseline_thrpt = m.throughput_mbps();
        }
        if strategy == Strategy::Hpm {
            hpm_summary = Some((
                m.traffic_reduction_vs(baseline_bytes),
                m.throughput_mbps() / baseline_thrpt.max(1e-9),
                m.local_fractions(),
            ));
        }
        table.row(vec![
            strategy.name().to_string(),
            format!("{:.2}", m.throughput_mbps()),
            format!("{:.4}", m.latency_secs()),
            format!("{:.1}%", m.origin_fraction() * 100.0),
            obsd::util::fmt_bytes(m.origin_bytes),
            if strategy.uses_prefetch() {
                format!("{:.3}", m.recall)
            } else {
                "-".into()
            },
            format!("{:.1}", m.wall_secs),
        ]);
    }
    println!("\n{}", table.render());

    if let Some((reduction, speedup, (c, p))) = hpm_summary {
        println!("headline (paper §VI: 60.7% OOI traffic reduction, 2689.8x throughput):");
        println!("  origin-traffic reduction vs No Cache : {:.1}%", reduction * 100.0);
        println!("  throughput vs No Cache               : {speedup:.0}x");
        println!(
            "  requests served at the local DTN     : {:.1}% ({:.1}% cached + {:.1}% pushed)",
            (c + p) * 100.0,
            c * 100.0,
            p * 100.0
        );
    }
    println!("\ntotal wall clock: {:.1} s", t_start.elapsed().as_secs_f64());
}
