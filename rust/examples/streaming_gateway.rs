//! Streaming-gateway scenario (paper §IV-B): a fleet of earthquake-
//! early-warning monitors polls the observatory every minute.  Without
//! the framework, every poll hits the origin; with HPM, the series are
//! detected as real-time, converted to push subscriptions, and served
//! from the local DTN.
//!
//! ```sh
//! cargo run --release --example streaming_gateway
//! ```

use obsd::cache::policy::PolicyKind;
use obsd::prefetch::Strategy;
use obsd::scenario::{Runner, Scenario};
use obsd::trace::presets;
use obsd::trace::{generator, UserKind};

fn main() {
    // A realtime-heavy observatory: crank the real-time volume share.
    let mut preset = presets::ooi();
    preset.name = "OOI"; // keep the WAN profile
    preset.program_mix.regular = 0.10;
    preset.program_mix.realtime = 0.80;
    preset.program_mix.overlapping = 0.10;
    preset.duration_days = 2.0;
    preset.n_users = 200;
    let trace = generator::generate(&preset);
    let rt_users = trace
        .users
        .iter()
        .filter(|u| u.kind == UserKind::ProgramRealtime)
        .count();
    let rt_requests = trace
        .requests
        .iter()
        .filter(|r| trace.user(r.user).kind == UserKind::ProgramRealtime)
        .count();
    println!(
        "monitoring fleet: {rt_users} real-time monitors, {rt_requests} of {} requests are 1-minute polls",
        trace.requests.len()
    );

    let runner = Runner::new();
    for strategy in [Strategy::NoCache, Strategy::CacheOnly, Strategy::Hpm] {
        let mut sc = Scenario::preset(strategy);
        sc.policy = PolicyKind::Lru;
        sc.cache_bytes = 2 << 30;
        let m = runner.run_trace(&trace, &sc).metrics;
        let (c, p) = m.local_fractions();
        println!(
            "\n{:<11}  origin requests {:>6.1}%   throughput {:>10.2} Mbps   queue latency {:>7.4} s\n             local service {:>6.1}% ({:.1}% cached, {:.1}% pushed/pre-fetched)",
            strategy.name(),
            m.origin_fraction() * 100.0,
            m.throughput_mbps(),
            m.latency_secs(),
            (c + p) * 100.0,
            c * 100.0,
            p * 100.0,
        );
    }
    println!(
        "\nThe streaming mechanism converts pull-based polling into push\n\
         subscriptions: the observatory sees one coalesced publication-\n\
         cadence transfer per (stream, DTN) instead of per-user polls."
    );
}
