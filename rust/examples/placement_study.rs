//! Data-placement ablation (paper §IV-C2, Table IV): virtual groups +
//! local data hubs on the GAGE workload, placement on vs off across
//! cache sizes.
//!
//! ```sh
//! cargo run --release --example placement_study
//! ```

use obsd::cache::policy::PolicyKind;
use obsd::prefetch::Strategy;
use obsd::scenario::{Runner, Scenario};
use obsd::trace::{generator, presets};
use obsd::util::table::Table;

fn main() {
    let mut preset = presets::gage();
    preset.duration_days = 7.0;
    let trace = generator::generate(&preset);
    println!(
        "GAGE workload: {} users / {} requests over {:.0} days\n",
        trace.users.len(),
        trace.requests.len(),
        trace.duration / 86_400.0
    );

    let mut t = Table::new("Data placement strategy ablation (HPM, LRU)").header(&[
        "Cache/DTN",
        "Peer thrpt W/O DP",
        "Peer thrpt W/ DP",
        "Peer improv.",
        "Total thrpt W/O DP",
        "Total thrpt W/ DP",
        "Replicated",
        "Groups engaged",
    ]);
    let runner = Runner::new();
    for gb in [0.25f64, 0.5, 1.0, 2.0] {
        let size = (gb * (1u64 << 30) as f64) as u64;
        let mk = |placement: bool| {
            let mut sc = Scenario::preset(Strategy::Hpm);
            sc.policy = PolicyKind::Lru;
            sc.cache_bytes = size;
            sc.placement = placement;
            runner.run_trace(&trace, &sc).metrics
        };
        let wo = mk(false);
        let w = mk(true);
        let peer_wo = obsd::util::bytes_per_sec_to_mbps(wo.peer_throughput.mean());
        let peer_w = obsd::util::bytes_per_sec_to_mbps(w.peer_throughput.mean());
        t.row(vec![
            format!("{gb} GB"),
            format!("{peer_wo:.1} Mbps"),
            format!("{peer_w:.1} Mbps"),
            if peer_wo > 0.0 {
                format!("{:+.1}%", (peer_w / peer_wo - 1.0) * 100.0)
            } else {
                "n/a".into()
            },
            format!("{:.1} Mbps", wo.throughput_mbps()),
            format!("{:.1} Mbps", w.throughput_mbps()),
            obsd::util::fmt_bytes(w.placement_bytes),
            format!("{}", (w.placement_bytes > 0.0) as u8),
        ]);
    }
    println!("{}", t.render());
    println!(
        "The hub replication concentrates each virtual group's hot data on\n\
         the best-connected DTN (eq. 2, θ_p=0.6 θ_u=0.2 θ_f=0.2), which lifts\n\
         peer-retrieval throughput — the effect the paper quantifies in Table IV."
    );
}
